"""AOT pipeline: train once, lower every model variant to HLO TEXT, export
weights + golden I/O + the serialized test set, and write a manifest the
Rust runtime consumes.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE, at build time (`make artifacts`); the Rust binary is
self-contained afterwards — Python is never on the request path.

Outputs under --out (default ../artifacts):
  lstm_L{l}_H{h}_B{b}.hlo.txt   one per variant (weights are HLO params)
  weights_L{l}_H{h}.mrnw        MRNW weight file per shape
  golden_L2_H32.bin             MRNG golden inputs+logits (trained model)
  har_test.bin                  MRNH serialized synthetic HAR test set
  manifest.json                 index of all of the above
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import struct
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod
from .kernels import lstm_cell as kmod
from .model import ModelConfig

# Serving variants: the trained default model at the batch sizes the
# dynamic batcher pads to (rust/src/coordinator/batcher.rs).
SERVING_BATCHES = [1, 2, 4, 8]

# Complexity variants (paper Fig 5 sweep) exported at batch 1 for the
# real-latency benches. Seeded (untrained) weights — latency is
# weight-independent; numerics are still golden-checked on the trained
# default.
COMPLEXITY_VARIANTS = [(1, 32), (3, 32), (2, 64), (2, 128)]
FULL_EXTRA_VARIANTS = [(2, 256), (1, 64), (3, 64)]

DEFAULT_CFG = ModelConfig()  # 2 layers x 32 hidden (paper §4.1 default)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: ModelConfig, batch: int) -> str:
    """Lower logits = f(x, w0, b0, ..., w_out, b_out) for one variant."""
    fn = model_mod.aot_fn(cfg, cell="pallas")
    x_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len, cfg.input_dim), jnp.float32)
    param_specs = [
        jax.ShapeDtypeStruct(p.shape, p.dtype)
        for p in model_mod.flat_param_list(
            model_mod.init_params(cfg, jax.random.PRNGKey(0))
        )
    ]
    lowered = jax.jit(fn).lower(x_spec, *param_specs)
    return to_hlo_text(lowered)


def write_mrnw(path: str, names: List[str], tensors: List[np.ndarray]) -> None:
    """MRNW v1 weight container, little-endian:
      magic[4] "MRNW" | u32 version | u32 n_tensors
      per tensor: u16 name_len | name bytes | u8 ndim | u32 dims[ndim]
                  | f32 data (C order)
    """
    assert len(names) == len(tensors)
    with open(path, "wb") as f:
        f.write(b"MRNW")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, t in zip(names, tensors):
            t = np.ascontiguousarray(t, dtype="<f4")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", t.ndim))
            for d in t.shape:
                f.write(struct.pack("<I", d))
            f.write(t.tobytes())


def read_mrnw(path: str) -> Dict[str, np.ndarray]:
    """Inverse of write_mrnw (round-trip tested)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"MRNW"
        ver, n = struct.unpack("<II", f.read(8))
        assert ver == 1
        for _ in range(n):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            count = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(f.read(4 * count), dtype="<f4").reshape(dims)
    return out


def write_golden(path: str, x: np.ndarray, logits: np.ndarray) -> None:
    """MRNG v1 golden I/O, little-endian:
      magic[4] "MRNG" | u32 version | u32 B | u32 T | u32 D | u32 C
      | f32 x[B*T*D] | f32 logits[B*C]
    """
    b, t, d = x.shape
    b2, c = logits.shape
    assert b == b2
    with open(path, "wb") as f:
        f.write(b"MRNG")
        f.write(struct.pack("<IIIII", 1, b, t, d, c))
        f.write(np.ascontiguousarray(x, dtype="<f4").tobytes())
        f.write(np.ascontiguousarray(logits, dtype="<f4").tobytes())


def sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def export_variant(cfg: ModelConfig, batch: int, out_dir: str) -> Dict:
    name = cfg.variant_name(batch)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = lower_variant(cfg, batch)
    with open(hlo_path, "w") as f:
        f.write(text)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    flat = model_mod.flat_param_list(params)
    return {
        "name": name,
        "num_layers": cfg.num_layers,
        "hidden": cfg.hidden,
        "batch": batch,
        "seq_len": cfg.seq_len,
        "input_dim": cfg.input_dim,
        "num_classes": cfg.num_classes,
        "hlo": f"{name}.hlo.txt",
        "weights": f"{cfg.weights_name()}.mrnw",
        "param_names": model_mod.flat_param_names(cfg),
        "param_shapes": [list(p.shape) for p in flat],
        "param_count": cfg.param_count(),
        "block_h": kmod.pick_block_h(cfg.hidden),
        "vmem_bytes": kmod.vmem_bytes(batch, cfg.input_dim, cfg.hidden),
        "mxu_utilization": kmod.mxu_utilization_estimate(
            batch, cfg.input_dim, cfg.hidden
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training + small test set (CI / pytest)")
    ap.add_argument("--full", action="store_true",
                    help="also export the large (H=256) complexity variants")
    ap.add_argument("--train-steps", type=int, default=None)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    fast = args.fast
    steps = args.train_steps or (40 if fast else 300)
    test_size = 64 if fast else data_mod.TEST_SIZE
    train_size = 256 if fast else 2048

    # 1. Train the default model on synthetic HAR.
    print(f"[aot] training default model ({DEFAULT_CFG.num_layers}l/"
          f"{DEFAULT_CFG.hidden}h, {steps} steps)...")
    trained_params, report = train_mod.train(
        DEFAULT_CFG, steps=steps, seed=args.seed,
        train_size=train_size, test_size=test_size,
    )

    manifest: Dict = {
        "format": "mobirnn-artifacts",
        "version": 1,
        "default_variant": DEFAULT_CFG.variant_name(1),
        "variants": [],
        "train_report": {
            k: v for k, v in report.items() if k != "loss_curve"
        },
        "loss_curve": report["loss_curve"],
    }

    # 2. Export serving variants (trained weights).
    weights_written = set()
    for b in SERVING_BATCHES:
        print(f"[aot] lowering {DEFAULT_CFG.variant_name(b)}...")
        entry = export_variant(DEFAULT_CFG, b, args.out)
        entry["trained"] = True
        manifest["variants"].append(entry)
    wpath = os.path.join(args.out, f"{DEFAULT_CFG.weights_name()}.mrnw")
    write_mrnw(
        wpath,
        model_mod.flat_param_names(DEFAULT_CFG),
        [np.asarray(t) for t in model_mod.flat_param_list(trained_params)],
    )
    weights_written.add(DEFAULT_CFG.weights_name())

    # 3. Export complexity variants (seeded weights) for latency benches.
    extra = list(COMPLEXITY_VARIANTS) + (FULL_EXTRA_VARIANTS if args.full else [])
    if fast:
        extra = extra[:1]
    for layers, hidden in extra:
        cfg = ModelConfig(num_layers=layers, hidden=hidden)
        print(f"[aot] lowering {cfg.variant_name(1)}...")
        entry = export_variant(cfg, 1, args.out)
        entry["trained"] = False
        manifest["variants"].append(entry)
        if cfg.weights_name() not in weights_written:
            params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
            write_mrnw(
                os.path.join(args.out, f"{cfg.weights_name()}.mrnw"),
                model_mod.flat_param_names(cfg),
                [np.asarray(t) for t in model_mod.flat_param_list(params)],
            )
            weights_written.add(cfg.weights_name())

    # 4. Golden I/O for the trained default: 8 test windows through the
    #    PALLAS-cell graph (the exact graph the artifact contains).
    x_te, y_te = data_mod.generate(8, args.seed + 1)
    logits = np.asarray(
        model_mod.forward(trained_params, jnp.asarray(x_te), cell="pallas")
    )
    golden_path = os.path.join(args.out, "golden_L2_H32.bin")
    write_golden(golden_path, x_te, logits)
    manifest["golden"] = {
        "file": "golden_L2_H32.bin",
        "variant": DEFAULT_CFG.variant_name(8),
        "batch": 8,
        "labels": [int(v) for v in y_te],
        "predictions": [int(v) for v in np.argmax(logits, axis=-1)],
    }

    # 5. Serialized synthetic HAR test set for serving (paper: 2947 windows).
    x_full, y_full = data_mod.generate(test_size, args.seed + 1)
    har_path = os.path.join(args.out, "har_test.bin")
    data_mod.write_har_bin(har_path, x_full, y_full)
    manifest["har_test"] = {
        "file": "har_test.bin",
        "n": int(test_size),
        "seq_len": data_mod.SEQ_LEN,
        "channels": data_mod.NUM_CHANNELS,
        "classes": data_mod.NUM_CLASSES,
    }

    # 6. Content hashes (lets `make artifacts` stay a no-op when unchanged).
    manifest["hashes"] = {
        e["hlo"]: sha256_file(os.path.join(args.out, e["hlo"]))
        for e in manifest["variants"]
    }

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {len(manifest['variants'])} variants + weights + "
          f"golden + har_test to {args.out}")


if __name__ == "__main__":
    main()
