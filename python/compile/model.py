"""L2: the paper's activity-recognition model in JAX.

A stacked LSTM (paper §2.1/§4.1: default 2 layers x 32 hidden units, input
128 timesteps x 9 sensor channels, 6 activity classes) followed by a linear
classifier head over the final hidden state. The per-timestep cell is the
fused Pallas kernel (kernels.lstm_cell) so that the AOT artifact contains
the L1 kernel's lowering; a `cell="ref"` path exists for training and for
differential testing against the oracle.

The time loop is a `lax.scan` (not an unroll): 128 steps x up to 3 layers
unrolled would blow up the HLO and compile time, and scan keeps the c/h
carry buffers donated/reused — the paper's §3.2 preallocation argument,
expressed at the XLA level.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import lstm_cell as kmod
from .kernels import ref as rmod

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration of one model variant (paper §4.1)."""

    num_layers: int = 2
    hidden: int = 32
    input_dim: int = 9
    seq_len: int = 128
    num_classes: int = 6

    def variant_name(self, batch: int) -> str:
        return f"lstm_L{self.num_layers}_H{self.hidden}_B{batch}"

    def weights_name(self) -> str:
        return f"weights_L{self.num_layers}_H{self.hidden}"

    def param_count(self) -> int:
        """Exact trainable parameter count (paper quotes ~17k for 2l/32h
        and ~1M for 2l/256h; this reproduces those)."""
        n = 0
        in_dim = self.input_dim
        for _ in range(self.num_layers):
            n += (in_dim + self.hidden) * 4 * self.hidden + 4 * self.hidden
            in_dim = self.hidden
        n += self.hidden * self.num_classes + self.num_classes
        return n


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Glorot-uniform weights, zero biases. Layout documented in ref.py."""
    layers: List[Dict[str, jax.Array]] = []
    in_dim = cfg.input_dim
    for _ in range(cfg.num_layers):
        key, k1 = jax.random.split(key)
        fan_in = in_dim + cfg.hidden
        scale = jnp.sqrt(6.0 / (fan_in + 4 * cfg.hidden))
        w = jax.random.uniform(
            k1, (fan_in, 4 * cfg.hidden), jnp.float32, -scale, scale
        )
        b = jnp.zeros((4 * cfg.hidden,), jnp.float32)
        layers.append({"w": w, "b": b})
        in_dim = cfg.hidden
    key, k2 = jax.random.split(key)
    scale = jnp.sqrt(6.0 / (cfg.hidden + cfg.num_classes))
    w_out = jax.random.uniform(
        k2, (cfg.hidden, cfg.num_classes), jnp.float32, -scale, scale
    )
    b_out = jnp.zeros((cfg.num_classes,), jnp.float32)
    return {"layers": layers, "w_out": w_out, "b_out": b_out}


def _cell_fn(name: str):
    if name == "pallas":
        return lambda x, h, c, w, b: kmod.lstm_cell(x, h, c, w, b)
    if name == "ref":
        return rmod.lstm_cell_ref
    raise ValueError(f"unknown cell impl {name!r}")


def forward(params: Params, x_seq: jax.Array, *, cell: str = "pallas") -> jax.Array:
    """Stacked-LSTM classifier forward pass.

    Args:
      params: as produced by init_params
      x_seq: [B, T, D]
      cell: "pallas" (fused L1 kernel) or "ref" (jnp oracle)
    Returns:
      logits [B, num_classes]
    """
    layers = params["layers"]
    num_layers = len(layers)
    batch = x_seq.shape[0]
    hidden = layers[0]["b"].shape[0] // 4
    step = _cell_fn(cell)

    h0 = jnp.zeros((num_layers, batch, hidden), x_seq.dtype)
    c0 = jnp.zeros((num_layers, batch, hidden), x_seq.dtype)

    def scan_body(carry, x_t):
        hs, cs = carry
        inp = x_t
        new_h, new_c = [], []
        for li, p in enumerate(layers):
            h_n, c_n = step(inp, hs[li], cs[li], p["w"], p["b"])
            new_h.append(h_n)
            new_c.append(c_n)
            inp = h_n
        return (jnp.stack(new_h), jnp.stack(new_c)), None

    # scan over time: [B, T, D] -> [T, B, D]
    xs = jnp.swapaxes(x_seq, 0, 1)
    (hs, _cs), _ = jax.lax.scan(scan_body, (h0, c0), xs)
    h_last = hs[-1]
    return h_last @ params["w_out"] + params["b_out"]


def loss_fn(params: Params, x_seq: jax.Array, labels: jax.Array,
            *, cell: str = "ref") -> jax.Array:
    """Mean softmax cross-entropy (training uses the ref cell: identical
    numerics, cheaper trace)."""
    logits = forward(params, x_seq, cell=cell)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params: Params, x_seq: jax.Array, labels: jax.Array,
             *, cell: str = "ref") -> jax.Array:
    logits = forward(params, x_seq, cell=cell)
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def flat_param_list(params: Params) -> List[jax.Array]:
    """Deterministic flattening used by the AOT artifact signature and the
    MRNW weight file: w0, b0, w1, b1, ..., w_out, b_out."""
    out: List[jax.Array] = []
    for p in params["layers"]:
        out.append(p["w"])
        out.append(p["b"])
    out.append(params["w_out"])
    out.append(params["b_out"])
    return out


def flat_param_names(cfg: ModelConfig) -> List[str]:
    names: List[str] = []
    for li in range(cfg.num_layers):
        names.append(f"layer{li}.w")
        names.append(f"layer{li}.b")
    names.append("head.w")
    names.append("head.b")
    return names


def unflatten_params(cfg: ModelConfig, flat: List[jax.Array]) -> Params:
    """Inverse of flat_param_list for a given config."""
    layers = []
    idx = 0
    for _ in range(cfg.num_layers):
        layers.append({"w": flat[idx], "b": flat[idx + 1]})
        idx += 2
    return {"layers": layers, "w_out": flat[idx], "b_out": flat[idx + 1]}


def aot_fn(cfg: ModelConfig, *, cell: str = "pallas"):
    """The function that gets AOT-lowered: logits = f(x, w0, b0, ..., wo, bo).

    Weights are HLO *parameters* (not baked constants) so one artifact per
    (shape-variant) serves any weight values; Rust loads the MRNW file and
    passes the tensors in the order of flat_param_names.
    """

    def fn(x_seq, *flat):
        params = unflatten_params(cfg, list(flat))
        return (forward(params, x_seq, cell=cell),)

    return fn
