"""Synthetic HAR dataset substrate (paper §4.1 substitution, DESIGN.md §2).

The paper evaluates on the UCI smartphone HAR dataset [4]: 7352 train /
2947 test windows, each 128 timesteps x 9 sensor channels (body acc x/y/z,
gyro x/y/z, total acc x/y/z), 6 activity classes (walking, walking-up,
walking-down, sitting, standing, laying). We do not have the dataset in
this image, so we generate a synthetic equivalent with the same shapes,
split sizes and label space, designed so that per-class structure lives in
exactly the places real HAR structure does: per-channel oscillation
frequency, amplitude and DC offset.

Class signatures (loosely mirroring the physical activities):
  0 walking          medium freq, medium amplitude, all channels
  1 walking_upstairs medium-high freq, higher gyro amplitude
  2 walking_down     medium-high freq, higher acc amplitude
  3 sitting          near-DC, tiny noise, distinct gravity split
  4 standing         near-DC, tiny noise, different gravity split
  5 laying           near-DC, gravity rotated onto a different axis

Everything is deterministic in (seed, index) so Python and Rust can agree
byte-for-byte on the serialized test set (artifacts/har_test.bin).
"""

from __future__ import annotations

import numpy as np

SEQ_LEN = 128
NUM_CHANNELS = 9
NUM_CLASSES = 6
TRAIN_SIZE = 7352
TEST_SIZE = 2947

CLASS_NAMES = [
    "walking",
    "walking_upstairs",
    "walking_downstairs",
    "sitting",
    "standing",
    "laying",
]

# Per-class (base_freq_hz, acc_amp, gyro_amp, gravity_axis) at 50 Hz sampling.
_SIGNATURES = [
    (1.9, 0.9, 0.8, 2),   # walking
    (2.4, 0.8, 1.3, 2),   # upstairs: more gyro
    (2.6, 1.4, 0.8, 2),   # downstairs: more acc
    (0.08, 0.05, 0.04, 1),  # sitting
    (0.06, 0.04, 0.03, 2),  # standing
    (0.05, 0.03, 0.03, 0),  # laying: gravity on x
]

_SAMPLE_HZ = 50.0
# Dynamic activities (walking*) ride on real body motion -> noisy sensors;
# static ones (sitting/standing/laying) are near-still, matching real HAR.
_NOISE_STD_DYNAMIC = 0.12
_NOISE_STD_STATIC = 0.03


def make_window(label: int, rng: np.random.RandomState) -> np.ndarray:
    """One [SEQ_LEN, NUM_CHANNELS] window for `label`."""
    freq, acc_amp, gyro_amp, grav_axis = _SIGNATURES[label]
    t = np.arange(SEQ_LEN, dtype=np.float64) / _SAMPLE_HZ
    freq = freq * (1.0 + 0.15 * rng.randn())
    phase = rng.uniform(0, 2 * np.pi, size=NUM_CHANNELS)
    out = np.zeros((SEQ_LEN, NUM_CHANNELS), dtype=np.float64)
    for ch in range(NUM_CHANNELS):
        if ch < 3:  # body acceleration
            amp = acc_amp * (0.7 + 0.3 * rng.rand())
            harm = 0.3 * acc_amp * np.sin(2 * np.pi * 2 * freq * t + phase[(ch + 3) % 9])
            out[:, ch] = amp * np.sin(2 * np.pi * freq * t + phase[ch]) + harm
        elif ch < 6:  # gyroscope
            amp = gyro_amp * (0.7 + 0.3 * rng.rand())
            out[:, ch] = amp * np.sin(2 * np.pi * freq * t + phase[ch])
        else:  # total acceleration = body + gravity projection
            amp = acc_amp * (0.7 + 0.3 * rng.rand())
            grav = 1.0 if (ch - 6) == grav_axis else 0.05
            out[:, ch] = grav + amp * np.sin(2 * np.pi * freq * t + phase[ch])
    noise = _NOISE_STD_DYNAMIC if label <= 2 else _NOISE_STD_STATIC
    out += noise * rng.randn(SEQ_LEN, NUM_CHANNELS)
    return out.astype(np.float32)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` windows: (x [n, SEQ_LEN, NUM_CHANNELS] f32, y [n] int32).

    Labels cycle round-robin then get shuffled, so class balance matches
    the (roughly balanced) UCI HAR dataset.
    """
    rng = np.random.RandomState(seed)
    labels = np.arange(n, dtype=np.int32) % NUM_CLASSES
    rng.shuffle(labels)
    x = np.stack([make_window(int(lbl), rng) for lbl in labels])
    return x, labels


def train_test(seed: int = 7, train_size: int = TRAIN_SIZE,
               test_size: int = TEST_SIZE):
    """The paper's 7352/2947 split (sizes overridable for fast tests)."""
    x_tr, y_tr = generate(train_size, seed)
    x_te, y_te = generate(test_size, seed + 1)
    return (x_tr, y_tr), (x_te, y_te)


def write_har_bin(path: str, x: np.ndarray, y: np.ndarray) -> None:
    """Serialize a dataset for the Rust loader (rust/src/har/).

    Format "MRNH" v1, little-endian:
      magic[4] "MRNH" | u32 version | u32 n | u32 seq_len | u32 channels
      | u32 classes | f32 x[n*seq_len*channels] | u8 y[n]
    """
    n, t, d = x.shape
    with open(path, "wb") as f:
        f.write(b"MRNH")
        for v in (1, n, t, d, NUM_CLASSES):
            f.write(np.uint32(v).tobytes())
        f.write(x.astype("<f4").tobytes())
        f.write(y.astype(np.uint8).tobytes())


def read_har_bin(path: str):
    """Inverse of write_har_bin (round-trip tested)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"MRNH", magic
        ver, n, t, d, c = np.frombuffer(f.read(20), dtype="<u4")
        assert ver == 1 and c == NUM_CLASSES
        x = np.frombuffer(f.read(4 * n * t * d), dtype="<f4").reshape(n, t, d)
        y = np.frombuffer(f.read(n), dtype=np.uint8).astype(np.int32)
    return x, y
