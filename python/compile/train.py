"""Trainer for the activity-recognition LSTM (paper §4.1 substitution:
TensorFlow-on-a-server -> JAX-on-this-image; same model family, same
parameter counts).

Plain hand-rolled Adam (no optax dependency) over minibatches of the
synthetic HAR training set. Training uses the `ref` cell (identical
numerics to the Pallas kernel — asserted by tests — but much cheaper to
trace/differentiate); the AOT export then wires the same weights into the
Pallas-kernel graph.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .model import ModelConfig, Params


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(
    cfg: ModelConfig,
    *,
    steps: int = 300,
    batch_size: int = 64,
    lr: float = 1e-2,
    seed: int = 7,
    train_size: int = 2048,
    test_size: int = 512,
    log_every: int = 25,
    verbose: bool = True,
) -> Tuple[Params, Dict[str, Any]]:
    """Train and return (params, report).

    `train_size`/`test_size` default well below the paper's 7352/2947 —
    the synthetic task saturates quickly and artifact builds should be
    fast; the full-size split is still what gets serialized for serving
    (see aot.py).
    """
    (x_tr, y_tr), (x_te, y_te) = data_mod.train_test(
        seed=seed, train_size=train_size, test_size=test_size
    )
    key = jax.random.PRNGKey(seed)
    params = model_mod.init_params(cfg, key)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, xb, yb):
        loss, grads = jax.value_and_grad(model_mod.loss_fn)(params, xb, yb)
        params, opt = adam_step(params, grads, opt, lr=lr)
        return params, opt, loss

    rng = np.random.RandomState(seed)
    losses = []
    for step in range(steps):
        idx = rng.randint(0, x_tr.shape[0], size=batch_size)
        params, opt, loss = step_fn(params, opt, x_tr[idx], y_tr[idx])
        losses.append(float(loss))
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"  step {step:4d}  loss {float(loss):.4f}")

    # Evaluate in chunks to bound memory.
    def eval_acc(x, y, chunk=256):
        correct = 0
        for i in range(0, x.shape[0], chunk):
            acc = model_mod.accuracy(params, x[i : i + chunk], y[i : i + chunk])
            correct += float(acc) * min(chunk, x.shape[0] - i)
        return correct / x.shape[0]

    report = {
        "steps": steps,
        "batch_size": batch_size,
        "lr": lr,
        "final_loss": losses[-1],
        "loss_curve": losses,
        "train_accuracy": eval_acc(x_tr, y_tr),
        "test_accuracy": eval_acc(x_te, y_te),
        "param_count": cfg.param_count(),
    }
    if verbose:
        print(
            f"  trained {cfg.num_layers}l/{cfg.hidden}h: "
            f"train_acc={report['train_accuracy']:.3f} "
            f"test_acc={report['test_accuracy']:.3f} "
            f"params={report['param_count']}"
        )
    return params, report
