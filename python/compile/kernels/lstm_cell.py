"""L1: fused Pallas LSTM cell kernel.

This kernel is the TPU re-expression of MobiRNN's §3.2/§3.3 GPU
optimizations (see DESIGN.md §Hardware-Adaptation):

- "combining inputs and weights"  -> a single [B, I+H] @ [I+H, 4, Ht] MXU
  contraction per grid cell instead of separate x- and h- matmuls;
- "pack vector products into few coarse work units" (RenderScript, Fig 2c)
  -> the Pallas *grid* tiles the hidden dimension into `block_h`-wide
  work units; one grid cell = one coarse unit; the grid IS the launch
  schedule (contrast: the CUDA-style Fig 2b factorization is one unit per
  output column);
- "fuse point-wise operations"    -> sigmoid/tanh/*/+ all live in the same
  kernel body; gates never round-trip through HBM;
- "avoid divergence statements"   -> the body is straight-line vector code
  (the numerically-stable sigmoid is a vectorized `where`, not a branch);
- "preallocate and reuse c/h"     -> c/h tiles live in the kernel's output
  refs; across timesteps they are the scan carry, never re-allocated.

The kernel MUST be lowered with interpret=True on this image: real-TPU
Pallas emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Correctness versus the pure-jnp oracle (`ref.py`) is asserted by
python/tests/test_kernel.py (hypothesis sweeps shapes and dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FORGET_BIAS

# Hidden-dimension tile width. 128 matches the TPU lane width so each
# grid cell feeds the MXU a [B, I+H] x [I+H, 4*128] contraction; smaller
# H uses a single tile. See DESIGN.md §Perf for the VMEM budget.
MAX_BLOCK_H = 128


def pick_block_h(hidden: int) -> int:
    """Largest divisor of `hidden` that is <= MAX_BLOCK_H.

    The paper's coarse factorization packs work into `#slots` units;
    here the analogous decision is the hidden-tile width. Every hidden
    size used in the paper (32..256) is a power of two, so this returns
    min(hidden, 128) for those; the general divisor walk keeps hypothesis
    sweeps over odd sizes valid.
    """
    if hidden <= MAX_BLOCK_H:
        return hidden
    for cand in range(MAX_BLOCK_H, 0, -1):
        if hidden % cand == 0:
            return cand
    return 1  # unreachable: 1 always divides


def _cell_kernel(xh_ref, w_ref, b_ref, c_ref, h_out_ref, c_out_ref):
    """Kernel body for one hidden tile.

    Refs (shapes per grid cell):
      xh_ref:    [B, I+H]      combined input||hidden (full row, every cell)
      w_ref:     [I+H, 4, Ht]  gate-major weight tile
      b_ref:     [4, Ht]       bias tile
      c_ref:     [B, Ht]       previous cell-state tile
      h_out_ref: [B, Ht]       next hidden tile
      c_out_ref: [B, Ht]       next cell-state tile
    """
    xh = xh_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    c_prev = c_ref[...]

    in_dim = xh.shape[-1]
    block_h = w.shape[-1]

    # Single fused contraction: [B, I+H] @ [I+H, 4*Ht] -> [B, 4, Ht].
    gates = jax.lax.dot_general(
        xh,
        w.reshape(in_dim, 4 * block_h),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(xh.shape[0], 4, block_h) + b[None, :, :].astype(jnp.float32)

    i_g = gates[:, 0, :]
    g_g = gates[:, 1, :]
    f_g = gates[:, 2, :]
    o_g = gates[:, 3, :]

    # Straight-line, divergence-free point-wise tail (stable sigmoid is a
    # vector select, not a branch).
    def sig(x):
        return jnp.where(
            x >= 0, 1.0 / (1.0 + jnp.exp(-x)), jnp.exp(x) / (1.0 + jnp.exp(x))
        )

    c_next = sig(f_g + FORGET_BIAS) * c_prev.astype(jnp.float32) + sig(i_g) * jnp.tanh(g_g)
    h_next = sig(o_g) * jnp.tanh(c_next)

    h_out_ref[...] = h_next.astype(h_out_ref.dtype)
    c_out_ref[...] = c_next.astype(c_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h",))
def lstm_cell(x, h, c, w, b, *, block_h: int | None = None):
    """Fused Pallas LSTM cell step.

    Args:
      x: [B, I]      timestep input
      h: [B, H]      previous hidden state
      c: [B, H]      previous cell state
      w: [I+H, 4H]   combined weights, gate order (i, g, f, o)
      b: [4H]        bias
      block_h: hidden tile width (None -> pick_block_h(H))
    Returns:
      (h_next, c_next), numerics identical to ref.lstm_cell_ref.
    """
    batch, hidden = h.shape
    in_dim = x.shape[-1] + hidden
    if block_h is None:
        block_h = pick_block_h(hidden)
    assert hidden % block_h == 0, (hidden, block_h)
    grid = (hidden // block_h,)

    # Gate-major layout so a hidden tile selects a contiguous block per gate:
    # [I+H, 4H] -> [I+H, 4, H].
    w_g = w.reshape(in_dim, 4, hidden)
    b_g = b.reshape(4, hidden)
    xh = jnp.concatenate([x, h], axis=-1)

    h_next, c_next = pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, in_dim), lambda j: (0, 0)),
            pl.BlockSpec((in_dim, 4, block_h), lambda j: (0, 0, j)),
            pl.BlockSpec((4, block_h), lambda j: (0, j)),
            pl.BlockSpec((batch, block_h), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((batch, block_h), lambda j: (0, j)),
            pl.BlockSpec((batch, block_h), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, hidden), h.dtype),
            jax.ShapeDtypeStruct((batch, hidden), c.dtype),
        ],
        interpret=True,  # CPU image: Mosaic lowering is TPU-only
    )(xh, w_g, b_g, c)
    return h_next, c_next


def vmem_bytes(batch: int, input_dim: int, hidden: int, block_h: int | None = None,
               bytes_per_elem: int = 4) -> int:
    """Estimated per-grid-cell VMEM footprint of the kernel (DESIGN.md §Perf).

    Counts all resident blocks: xh row, weight tile, bias tile, c tile and
    both output tiles, plus the [B, 4, Ht] gate accumulator.
    """
    if block_h is None:
        block_h = pick_block_h(hidden)
    in_dim = input_dim + hidden
    blocks = (
        batch * in_dim          # xh
        + in_dim * 4 * block_h  # w tile
        + 4 * block_h           # b tile
        + batch * block_h       # c in
        + 2 * batch * block_h   # h/c out
        + batch * 4 * block_h   # gate accumulator
    )
    return blocks * bytes_per_elem


def mxu_utilization_estimate(batch: int, input_dim: int, hidden: int,
                             block_h: int | None = None) -> float:
    """Fraction of MXU (128x128 systolic) lanes busy for the gate GEMM.

    The contraction is [B, I+H] @ [I+H, 4*Ht]. Row occupancy is B/128
    (serving batch), column occupancy min(1, 4*Ht/128). This is the
    structural estimate recorded in EXPERIMENTS.md §Perf — interpret-mode
    wallclock is NOT a TPU proxy.
    """
    if block_h is None:
        block_h = pick_block_h(hidden)
    rows = min(1.0, batch / 128.0)
    cols = min(1.0, (4 * block_h) / 128.0)
    return rows * cols
