"""Pure-jnp oracle for the fused LSTM cell and the stacked LSTM model.

This module is the correctness ground truth (paper §2.1, basic LSTM of
Zaremba et al. [18]). Everything here is straightforward, unfused jnp so
that the optimized Pallas kernel (`lstm_cell.py`) and the Rust native
engine can be validated against the same reference numerics.

Gate layout convention (used EVERYWHERE in this repo — python, HLO
artifacts, MRNW weight files and the Rust engine):

    gates = [x ; h] @ W + b            # W: [input+hidden, 4*hidden]
    i, g, f, o = split(gates, 4, axis=-1)   # input, candidate, forget, output
    c' = sigmoid(f + forget_bias) * c + sigmoid(i) * tanh(g)
    h' = sigmoid(o) * tanh(c')

`forget_bias = 1.0` matches the TensorFlow BasicLSTMCell the paper trained
with (§4.1, TF training on a server).
"""

from __future__ import annotations

import jax.numpy as jnp

FORGET_BIAS = 1.0


def sigmoid(x):
    """Numerically-stable logistic function."""
    return jnp.where(
        x >= 0, 1.0 / (1.0 + jnp.exp(-x)), jnp.exp(x) / (1.0 + jnp.exp(x))
    )


def lstm_cell_ref(x, h, c, w, b):
    """One LSTM cell step, unfused reference.

    Args:
      x: [B, I]  input at this timestep
      h: [B, H]  previous hidden state
      c: [B, H]  previous cell state
      w: [I+H, 4H] combined weight matrix (input rows first, hidden rows after)
      b: [4H]    bias
    Returns:
      (h_next, c_next): each [B, H]
    """
    xh = jnp.concatenate([x, h], axis=-1)
    gates = xh @ w + b
    i, g, f, o = jnp.split(gates, 4, axis=-1)
    c_next = sigmoid(f + FORGET_BIAS) * c + sigmoid(i) * jnp.tanh(g)
    h_next = sigmoid(o) * jnp.tanh(c_next)
    return h_next, c_next


def lstm_cell_ref_split(x, h, c, w_x, w_h, b):
    """Variant with SEPARATE input/hidden matmuls — the un-combined form that
    the paper's §3.3 "combining inputs and weights" optimization replaces.
    Used by the fusion ablation test to show numerical equivalence."""
    gates = x @ w_x + h @ w_h + b
    i, g, f, o = jnp.split(gates, 4, axis=-1)
    c_next = sigmoid(f + FORGET_BIAS) * c + sigmoid(i) * jnp.tanh(g)
    h_next = sigmoid(o) * jnp.tanh(c_next)
    return h_next, c_next


def stacked_lstm_ref(x_seq, params):
    """Run a stacked LSTM over a full sequence, reference semantics.

    Args:
      x_seq: [B, T, D] input sequence
      params: list over layers of dicts {"w": [I+H,4H], "b": [4H]}
    Returns:
      h_last: [B, H] final hidden state of the top layer
    """
    batch = x_seq.shape[0]
    hidden = params[0]["b"].shape[0] // 4
    hs = [jnp.zeros((batch, hidden), x_seq.dtype) for _ in params]
    cs = [jnp.zeros((batch, hidden), x_seq.dtype) for _ in params]
    for t in range(x_seq.shape[1]):
        inp = x_seq[:, t, :]
        for li, p in enumerate(params):
            hs[li], cs[li] = lstm_cell_ref(inp, hs[li], cs[li], p["w"], p["b"])
            inp = hs[li]
    return hs[-1]


def classifier_ref(x_seq, params, w_out, b_out):
    """Full activity-recognition model: stacked LSTM -> linear head.

    Returns logits [B, num_classes]."""
    h_last = stacked_lstm_ref(x_seq, params)
    return h_last @ w_out + b_out
