"""L2 correctness: stacked model — pallas graph vs oracle, shapes,
parameter bookkeeping, AOT signature stability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as m
from compile.kernels import ref as rmod
from compile.model import ModelConfig


def _x(rng, batch, cfg):
    return jnp.asarray(rng.randn(batch, cfg.seq_len, cfg.input_dim).astype("f"))


class TestForward:
    @pytest.mark.parametrize("layers,hidden", [(1, 32), (2, 32), (3, 32), (2, 64)])
    def test_pallas_matches_ref(self, layers, hidden):
        cfg = ModelConfig(num_layers=layers, hidden=hidden, seq_len=16)
        params = m.init_params(cfg, jax.random.PRNGKey(layers * 100 + hidden))
        x = _x(np.random.RandomState(0), 2, cfg)
        lr = m.forward(params, x, cell="ref")
        lp = m.forward(params, x, cell="pallas")
        np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-4)

    def test_ref_matches_loop_oracle(self):
        """The scan-based forward equals the naive python-loop oracle."""
        cfg = ModelConfig(seq_len=12)
        params = m.init_params(cfg, jax.random.PRNGKey(1))
        x = _x(np.random.RandomState(1), 3, cfg)
        scan_logits = m.forward(params, x, cell="ref")
        loop_logits = rmod.classifier_ref(
            x, params["layers"], params["w_out"], params["b_out"]
        )
        np.testing.assert_allclose(scan_logits, loop_logits, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("batch", [1, 2, 8])
    def test_output_shape(self, batch):
        cfg = ModelConfig(seq_len=8)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        logits = m.forward(params, _x(np.random.RandomState(0), batch, cfg))
        assert logits.shape == (batch, cfg.num_classes)

    def test_forward_deterministic(self):
        cfg = ModelConfig(seq_len=8)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        x = _x(np.random.RandomState(0), 2, cfg)
        a = np.asarray(m.forward(params, x))
        b = np.asarray(m.forward(params, x))
        np.testing.assert_array_equal(a, b)

    def test_unknown_cell_raises(self):
        cfg = ModelConfig(seq_len=4)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            m.forward(params, _x(np.random.RandomState(0), 1, cfg), cell="cuda")


class TestParams:
    def test_param_count_paper_default(self):
        """Paper §4.1: default 2l/32h model is ~ seventeen-thousand-scale;
        the exact TF BasicLSTMCell count with a 6-way head is 13894."""
        assert ModelConfig().param_count() == 13894

    def test_param_count_growth_ratio(self):
        """Paper §4.3: 2l/128h has ~4x the parameters of 2l/64h."""
        p64 = ModelConfig(hidden=64).param_count()
        p128 = ModelConfig(hidden=128).param_count()
        assert 3.5 < p128 / p64 < 4.5

    def test_param_count_matches_init(self):
        for cfg in [ModelConfig(), ModelConfig(num_layers=3, hidden=64)]:
            params = m.init_params(cfg, jax.random.PRNGKey(0))
            total = sum(int(np.prod(p.shape)) for p in m.flat_param_list(params))
            assert total == cfg.param_count()

    @settings(max_examples=10, deadline=None)
    @given(layers=st.integers(1, 3), hidden=st.sampled_from([8, 32, 64]))
    def test_flatten_roundtrip(self, layers, hidden):
        cfg = ModelConfig(num_layers=layers, hidden=hidden)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        flat = m.flat_param_list(params)
        assert len(flat) == len(m.flat_param_names(cfg))
        rt = m.unflatten_params(cfg, flat)
        for a, b in zip(m.flat_param_list(rt), flat):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_flat_names_order(self):
        names = m.flat_param_names(ModelConfig(num_layers=2))
        assert names == ["layer0.w", "layer0.b", "layer1.w", "layer1.b",
                         "head.w", "head.b"]


class TestAotFn:
    def test_aot_fn_signature(self):
        """aot_fn(x, *flat) must equal forward(params, x) — this is the
        exact function Rust executes via PJRT."""
        cfg = ModelConfig(seq_len=8)
        params = m.init_params(cfg, jax.random.PRNGKey(2))
        x = _x(np.random.RandomState(2), 2, cfg)
        (via_aot,) = m.aot_fn(cfg, cell="ref")(x, *m.flat_param_list(params))
        direct = m.forward(params, x, cell="ref")
        np.testing.assert_array_equal(np.asarray(via_aot), np.asarray(direct))

    def test_loss_decreases_on_overfit_batch(self):
        """Gradient sanity: 30 SGD steps on one batch reduce loss."""
        from compile import train as tmod
        cfg = ModelConfig(seq_len=16)
        params = m.init_params(cfg, jax.random.PRNGKey(3))
        rng = np.random.RandomState(3)
        x = _x(rng, 8, cfg)
        y = jnp.asarray(rng.randint(0, 6, size=8))
        opt = tmod.adam_init(params)
        l0 = float(m.loss_fn(params, x, y))
        for _ in range(30):
            loss, grads = jax.value_and_grad(m.loss_fn)(params, x, y)
            params, opt = tmod.adam_step(params, grads, opt, lr=1e-2)
        assert float(m.loss_fn(params, x, y)) < l0 * 0.5

    def test_accuracy_range(self):
        cfg = ModelConfig(seq_len=8)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        x = _x(rng, 16, cfg)
        y = jnp.asarray(rng.randint(0, 6, size=16))
        acc = float(m.accuracy(params, x, y))
        assert 0.0 <= acc <= 1.0
