"""AOT pipeline: MRNW/MRNG container round-trips, HLO text emission,
variant naming — the contracts the Rust side (runtime/, lstm/weights.rs)
parses byte-for-byte."""

import json
import os
import struct
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model as m
from compile.model import ModelConfig


class TestMrnw:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5))
    def test_roundtrip(self, seed, n):
        rng = np.random.RandomState(seed)
        names = [f"t{i}" for i in range(n)]
        tensors = [
            rng.randn(*rng.randint(1, 6, size=rng.randint(1, 4))).astype("f")
            for _ in range(n)
        ]
        path = f"/tmp/mrnw_rt_{seed}_{n}.mrnw"
        aot.write_mrnw(path, names, tensors)
        back = aot.read_mrnw(path)
        assert list(back.keys()) == names
        for name, t in zip(names, tensors):
            np.testing.assert_array_equal(back[name], t)
        os.unlink(path)

    def test_header_layout(self, tmp_path):
        p = str(tmp_path / "w.mrnw")
        aot.write_mrnw(p, ["ab"], [np.zeros((2, 3), "f")])
        raw = open(p, "rb").read()
        assert raw[:4] == b"MRNW"
        ver, n = struct.unpack("<II", raw[4:12])
        assert (ver, n) == (1, 1)
        (nlen,) = struct.unpack("<H", raw[12:14])
        assert raw[14:16] == b"ab" and nlen == 2
        assert raw[16] == 2  # ndim
        assert struct.unpack("<II", raw[17:25]) == (2, 3)
        assert len(raw) == 25 + 4 * 6

    def test_model_params_roundtrip(self, tmp_path):
        cfg = ModelConfig()
        params = m.init_params(cfg, __import__("jax").random.PRNGKey(0))
        p = str(tmp_path / "w.mrnw")
        names = m.flat_param_names(cfg)
        aot.write_mrnw(p, names, [np.asarray(t) for t in m.flat_param_list(params)])
        back = aot.read_mrnw(p)
        assert back["layer0.w"].shape == (9 + 32, 128)
        assert back["head.w"].shape == (32, 6)


class TestGolden:
    def test_golden_layout(self, tmp_path):
        x = np.arange(2 * 4 * 3, dtype="f").reshape(2, 4, 3)
        logits = np.arange(2 * 6, dtype="f").reshape(2, 6)
        p = str(tmp_path / "g.bin")
        aot.write_golden(p, x, logits)
        raw = open(p, "rb").read()
        assert raw[:4] == b"MRNG"
        hdr = struct.unpack("<IIIII", raw[4:24])
        assert hdr == (1, 2, 4, 3, 6)
        body = np.frombuffer(raw[24:], dtype="<f4")
        np.testing.assert_array_equal(body[: 2 * 4 * 3], x.ravel())
        np.testing.assert_array_equal(body[2 * 4 * 3:], logits.ravel())


class TestLowering:
    def test_hlo_text_emitted(self):
        cfg = ModelConfig(seq_len=4)
        text = aot.lower_variant(cfg, 1)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_variant_param_arity(self):
        """x + 2 tensors per layer + head (w, b)."""
        cfg = ModelConfig(num_layers=2, seq_len=4)
        text = aot.lower_variant(cfg, 1)
        # 7 entry parameters: x, w0, b0, w1, b1, w_out, b_out
        entry = text[text.index("ENTRY"):]
        body = entry[: entry.index("ROOT")]
        assert body.count("parameter(") == 7

    def test_variant_names(self):
        cfg = ModelConfig(num_layers=3, hidden=64)
        assert cfg.variant_name(4) == "lstm_L3_H64_B4"
        assert cfg.weights_name() == "weights_L3_H64"


@pytest.mark.slow
class TestEndToEndBuild:
    def test_fast_build_produces_manifest(self, tmp_path):
        """Run the full aot CLI in --fast mode into a temp dir and check
        every promised artifact exists and the manifest indexes them."""
        out = str(tmp_path / "artifacts")
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", out, "--fast",
             "--train-steps", "5"],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            capture_output=True, text=True, env=env, timeout=600,
        )
        assert r.returncode == 0, r.stderr[-2000:]
        man = json.load(open(os.path.join(out, "manifest.json")))
        assert man["format"] == "mobirnn-artifacts"
        for v in man["variants"]:
            assert os.path.exists(os.path.join(out, v["hlo"]))
            assert os.path.exists(os.path.join(out, v["weights"]))
        assert os.path.exists(os.path.join(out, man["golden"]["file"]))
        assert os.path.exists(os.path.join(out, man["har_test"]["file"]))
        golden = man["golden"]
        assert len(golden["labels"]) == golden["batch"]
