"""L1 correctness: the fused Pallas LSTM cell vs the pure-jnp oracle.

This is the CORE correctness signal for the whole stack: the AOT artifact
contains the Pallas kernel's lowering, the Rust native engine mirrors the
oracle, and the golden file ties Rust execution back to these numerics.
Hypothesis sweeps shapes and dtypes per the repro brief.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lstm_cell as kmod
from compile.kernels import ref as rmod


def _mk(rng, *shape):
    return jnp.asarray((rng.randn(*shape) * 0.5).astype(np.float32))


def _cell_inputs(rng, batch, input_dim, hidden):
    return (
        _mk(rng, batch, input_dim),
        _mk(rng, batch, hidden),
        _mk(rng, batch, hidden),
        _mk(rng, input_dim + hidden, 4 * hidden),
        _mk(rng, 4 * hidden),
    )


class TestCellVsRef:
    @pytest.mark.parametrize("batch", [1, 2, 8])
    @pytest.mark.parametrize("hidden", [32, 64, 128, 256])
    def test_paper_shapes(self, batch, hidden):
        """Every (batch, hidden) combination the paper evaluates."""
        rng = np.random.RandomState(batch * 1000 + hidden)
        x, h, c, w, b = _cell_inputs(rng, batch, 9, hidden)
        h_ref, c_ref = rmod.lstm_cell_ref(x, h, c, w, b)
        h_k, c_k = kmod.lstm_cell(x, h, c, w, b)
        np.testing.assert_allclose(h_k, h_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c_k, c_ref, rtol=1e-5, atol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(
        batch=st.integers(1, 6),
        input_dim=st.integers(1, 40),
        hidden=st.sampled_from([1, 2, 3, 5, 8, 16, 24, 32, 48, 96, 160]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, batch, input_dim, hidden, seed):
        """Arbitrary (including odd / non-power-of-two) shapes."""
        rng = np.random.RandomState(seed)
        x, h, c, w, b = _cell_inputs(rng, batch, input_dim, hidden)
        h_ref, c_ref = rmod.lstm_cell_ref(x, h, c, w, b)
        h_k, c_k = kmod.lstm_cell(x, h, c, w, b)
        np.testing.assert_allclose(h_k, h_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(c_k, c_ref, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_bfloat16(self, seed):
        """The kernel accumulates in f32 regardless of storage dtype."""
        rng = np.random.RandomState(seed)
        x, h, c, w, b = _cell_inputs(rng, 2, 9, 32)
        cast = lambda t: t.astype(jnp.bfloat16)
        h_ref, c_ref = rmod.lstm_cell_ref(
            cast(x).astype(jnp.float32), cast(h).astype(jnp.float32),
            cast(c).astype(jnp.float32), cast(w).astype(jnp.float32),
            cast(b).astype(jnp.float32))
        h_k, c_k = kmod.lstm_cell(cast(x), cast(h), cast(c), cast(w), cast(b))
        assert h_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            h_k.astype(jnp.float32), h_ref, rtol=5e-2, atol=5e-2)
        np.testing.assert_allclose(
            c_k.astype(jnp.float32), c_ref, rtol=5e-2, atol=5e-2)

    def test_explicit_block_h(self):
        """Forcing a smaller tile (more grid cells) must not change numerics."""
        rng = np.random.RandomState(3)
        x, h, c, w, b = _cell_inputs(rng, 2, 9, 64)
        h_ref, c_ref = rmod.lstm_cell_ref(x, h, c, w, b)
        for bh in (8, 16, 32, 64):
            h_k, c_k = kmod.lstm_cell(x, h, c, w, b, block_h=bh)
            np.testing.assert_allclose(h_k, h_ref, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(c_k, c_ref, rtol=1e-5, atol=1e-5)

    def test_zero_state(self):
        """First timestep of every sequence starts from h=c=0."""
        rng = np.random.RandomState(4)
        x = _mk(rng, 3, 9)
        h = jnp.zeros((3, 32))
        c = jnp.zeros((3, 32))
        w = _mk(rng, 41, 128)
        b = _mk(rng, 128)
        h_ref, c_ref = rmod.lstm_cell_ref(x, h, c, w, b)
        h_k, c_k = kmod.lstm_cell(x, h, c, w, b)
        np.testing.assert_allclose(h_k, h_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c_k, c_ref, rtol=1e-5, atol=1e-5)

    def test_multi_step_composition(self):
        """Chaining the kernel across 16 timesteps tracks the oracle —
        errors do not compound beyond tolerance."""
        rng = np.random.RandomState(5)
        w = _mk(rng, 41, 128)
        b = _mk(rng, 128)
        h_r = h_k = jnp.zeros((2, 32))
        c_r = c_k = jnp.zeros((2, 32))
        for t in range(16):
            x = _mk(rng, 2, 9)
            h_r, c_r = rmod.lstm_cell_ref(x, h_r, c_r, w, b)
            h_k, c_k = kmod.lstm_cell(x, h_k, c_k, w, b)
        np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(c_k, c_r, rtol=1e-4, atol=1e-4)


class TestFusionAblation:
    """Paper §3.3 'combining inputs and weights': the combined single-GEMM
    form is numerically identical to the split two-GEMM form."""

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), hidden=st.sampled_from([8, 32, 64]))
    def test_combined_equals_split(self, seed, hidden):
        rng = np.random.RandomState(seed)
        x, h, c, w, b = _cell_inputs(rng, 2, 9, hidden)
        w_x, w_h = w[:9, :], w[9:, :]
        h_s, c_s = rmod.lstm_cell_ref_split(x, h, c, w_x, w_h, b)
        h_f, c_f = kmod.lstm_cell(x, h, c, w, b)
        np.testing.assert_allclose(h_f, h_s, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c_f, c_s, rtol=1e-5, atol=1e-5)


class TestKernelStructure:
    def test_pick_block_h_divides(self):
        for hdn in range(1, 300):
            bh = kmod.pick_block_h(hdn)
            assert hdn % bh == 0
            assert bh <= kmod.MAX_BLOCK_H or hdn == bh

    def test_pick_block_h_paper_sizes(self):
        assert kmod.pick_block_h(32) == 32
        assert kmod.pick_block_h(64) == 64
        assert kmod.pick_block_h(128) == 128
        assert kmod.pick_block_h(256) == 128  # tiled into 2 grid cells

    def test_vmem_fits_budget(self):
        """Every paper variant's per-cell working set fits a 16 MiB VMEM."""
        for hidden in (32, 64, 128, 256):
            for batch in (1, 8):
                assert kmod.vmem_bytes(batch, 9, hidden) < 16 * 1024 * 1024

    def test_vmem_monotonic_in_batch(self):
        vals = [kmod.vmem_bytes(b, 9, 32) for b in (1, 2, 4, 8)]
        assert vals == sorted(vals)

    def test_mxu_utilization_bounds(self):
        for hidden in (32, 64, 128, 256):
            for batch in (1, 8, 128):
                u = kmod.mxu_utilization_estimate(batch, 9, hidden)
                assert 0.0 < u <= 1.0

    def test_mxu_utilization_improves_with_batch(self):
        """Serving batch is the row-occupancy lever (DESIGN.md §Perf)."""
        assert kmod.mxu_utilization_estimate(8, 9, 32) > \
            kmod.mxu_utilization_estimate(1, 9, 32)

    def test_cell_is_jittable_and_stable_under_jit(self):
        rng = np.random.RandomState(6)
        x, h, c, w, b = _cell_inputs(rng, 2, 9, 32)
        f = jax.jit(lambda *a: kmod.lstm_cell(*a))
        h1, c1 = f(x, h, c, w, b)
        h2, c2 = kmod.lstm_cell(x, h, c, w, b)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
