"""Trainer: loss goes down, accuracy beats chance on the synthetic task,
Adam bookkeeping is correct."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as m, train as t
from compile.model import ModelConfig


class TestAdam:
    def test_converges_on_quadratic(self):
        params = {"x": jnp.asarray(5.0)}
        opt = t.adam_init(params)
        for _ in range(400):
            grads = {"x": 2.0 * params["x"]}
            params, opt = t.adam_step(params, grads, opt, lr=0.05)
        assert abs(float(params["x"])) < 1e-2

    def test_step_counter(self):
        params = {"x": jnp.asarray(1.0)}
        opt = t.adam_init(params)
        assert opt["t"] == 0
        _, opt = t.adam_step(params, {"x": jnp.asarray(1.0)}, opt)
        assert opt["t"] == 1

    def test_zero_grad_no_move(self):
        params = {"x": jnp.asarray(3.0)}
        opt = t.adam_init(params)
        new, _ = t.adam_step(params, {"x": jnp.asarray(0.0)}, opt)
        assert float(new["x"]) == 3.0


class TestTrain:
    def test_short_train_learns(self):
        """A short-sequence model trained briefly on the synthetic task must
        beat chance (1/6) clearly — the e2e learnability signal."""
        cfg = ModelConfig(seq_len=128)
        params, report = t.train(
            cfg, steps=60, batch_size=32, train_size=192, test_size=96,
            seed=11, verbose=False,
        )
        assert report["final_loss"] < report["loss_curve"][0]
        assert report["test_accuracy"] > 0.4, report
        assert report["param_count"] == cfg.param_count()

    def test_loss_curve_length(self):
        _, report = t.train(
            ModelConfig(seq_len=16), steps=8, batch_size=8,
            train_size=32, test_size=16, verbose=False,
        )
        assert len(report["loss_curve"]) == 8
        assert all(np.isfinite(v) for v in report["loss_curve"])
