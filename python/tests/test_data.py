"""Synthetic HAR substrate: determinism, shape contract, learnable
structure, and the MRNH serialization round-trip that Rust depends on."""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as d


class TestGenerate:
    def test_shapes_and_dtypes(self):
        x, y = d.generate(24, seed=0)
        assert x.shape == (24, d.SEQ_LEN, d.NUM_CHANNELS)
        assert x.dtype == np.float32
        assert y.shape == (24,)
        assert set(np.unique(y)) <= set(range(d.NUM_CLASSES))

    def test_deterministic(self):
        x1, y1 = d.generate(12, seed=42)
        x2, y2 = d.generate(12, seed=42)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_seed_changes_data(self):
        x1, _ = d.generate(12, seed=1)
        x2, _ = d.generate(12, seed=2)
        assert np.abs(x1 - x2).max() > 0.1

    def test_class_balance(self):
        _, y = d.generate(600, seed=0)
        counts = np.bincount(y, minlength=d.NUM_CLASSES)
        assert counts.min() == counts.max() == 100

    def test_paper_split_sizes(self):
        """§4.1: 7352 train / 2947 test (constants, not a full generate)."""
        assert d.TRAIN_SIZE == 7352
        assert d.TEST_SIZE == 2947

    def test_values_bounded(self):
        x, _ = d.generate(50, seed=3)
        assert np.isfinite(x).all()
        assert np.abs(x).max() < 10.0

    def test_classes_are_separable(self):
        """A nearest-centroid classifier on trivial features must beat
        chance by a wide margin — i.e. the labels are learnable, so the
        trained LSTM's accuracy is meaningful."""
        x_tr, y_tr = d.generate(300, seed=0)
        x_te, y_te = d.generate(120, seed=1)

        def feats(x):
            # per-channel mean + std + mean |first difference| (~frequency)
            return np.concatenate(
                [x.mean(1), x.std(1), np.abs(np.diff(x, axis=1)).mean(1)], axis=1
            )

        f_tr, f_te = feats(x_tr), feats(x_te)
        cents = np.stack([f_tr[y_tr == c].mean(0) for c in range(d.NUM_CLASSES)])
        pred = np.argmin(
            ((f_te[:, None, :] - cents[None, :, :]) ** 2).sum(-1), axis=1
        )
        acc = (pred == y_te).mean()
        assert acc > 0.6, f"synthetic classes not separable: acc={acc}"

    def test_static_vs_dynamic_activities(self):
        """Static activities (sitting/standing/laying) have far less motion
        energy than walking ones — the structure real HAR data has."""
        x, y = d.generate(240, seed=5)
        energy = np.abs(np.diff(x[:, :, :6], axis=1)).mean(axis=(1, 2))
        walk = energy[y <= 2].mean()
        static = energy[y >= 3].mean()
        assert walk > 3 * static


class TestSerialization:
    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(1, 40), seed=st.integers(0, 1000))
    def test_har_bin_roundtrip(self, n, seed):
        x, y = d.generate(n, seed=seed)
        path = f"/tmp/har_rt_{n}_{seed}.bin"
        d.write_har_bin(path, x, y)
        x2, y2 = d.read_har_bin(path)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)
        os.unlink(path)

    def test_har_bin_header(self, tmp_path):
        x, y = d.generate(3, seed=0)
        p = tmp_path / "t.bin"
        d.write_har_bin(str(p), x, y)
        raw = p.read_bytes()
        assert raw[:4] == b"MRNH"
        header = np.frombuffer(raw[4:24], dtype="<u4")
        assert list(header) == [1, 3, d.SEQ_LEN, d.NUM_CHANNELS, d.NUM_CLASSES]
        assert len(raw) == 24 + 4 * 3 * d.SEQ_LEN * d.NUM_CHANNELS + 3

    def test_bad_magic_rejected(self, tmp_path):
        p = tmp_path / "bad.bin"
        p.write_bytes(b"NOPE" + b"\x00" * 40)
        with pytest.raises(AssertionError):
            d.read_har_bin(str(p))
