//! Vendored, dependency-free subset of the `anyhow` error-handling API.
//!
//! The build environment resolves dependencies offline, so the crate set
//! the workspace may use is whatever ships in-tree. This shim implements
//! exactly the surface `mobirnn` uses — [`Error`], [`Result`], the
//! [`anyhow!`] macro, the [`Context`] extension trait and
//! [`Error::downcast_ref`] — with the same observable semantics:
//!
//! - `Display` shows the OUTERMOST message (the latest context, or the
//!   root error when no context was attached);
//! - alternate `Display` (`{:#}`) shows the whole chain, colon-joined,
//!   outermost first — `"ctx2: ctx1: root"`;
//! - `downcast_ref::<E>()` sees through any number of context frames to
//!   the root error, so typed errors (e.g. `ServeError`) survive
//!   wrapping;
//! - `?` converts any `std::error::Error + Send + Sync + 'static` via
//!   the blanket `From` impl.
//!
//! Context messages are rendered to `String` eagerly (the real crate
//! keeps the objects; nothing here downcasts a context frame, so the
//! eager form is observationally identical).

use std::any::Any;
use std::convert::Infallible;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Object-safe view of a root error: printable and downcastable.
trait Root: Display + Debug + Send + Sync + 'static {
    fn as_any(&self) -> &(dyn Any + Send + Sync);
}

impl<M: Display + Debug + Send + Sync + 'static> Root for M {
    fn as_any(&self) -> &(dyn Any + Send + Sync) {
        self
    }
}

/// Boxed dynamic error with an attachable context chain.
pub struct Error {
    root: Box<dyn Root>,
    /// Context frames, INNERMOST first (`context` pushes to the back, so
    /// the last entry is the outermost message `Display` shows).
    context: Vec<String>,
}

impl Error {
    /// Wrap a standard error. The concrete type stays reachable through
    /// [`Error::downcast_ref`].
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Self { root: Box::new(error), context: Vec::new() }
    }

    /// Build from a printable message (what [`anyhow!`] expands to).
    pub fn msg<M: Display + Debug + Send + Sync + 'static>(message: M) -> Self {
        Self { root: Box::new(message), context: Vec::new() }
    }

    /// Attach a context message; it becomes the new outermost frame.
    pub fn context<C: Display + Send + Sync + 'static>(mut self, context: C) -> Self {
        self.context.push(context.to_string());
        self
    }

    /// A reference to the root error if it is an `E`, looking through
    /// every context frame.
    pub fn downcast_ref<E: Display + Debug + Send + Sync + 'static>(&self) -> Option<&E> {
        self.root.as_any().downcast_ref::<E>()
    }

    /// Outermost frame first, root last.
    fn frames(&self) -> impl Iterator<Item = &str> {
        self.context.iter().rev().map(String::as_str)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(outermost) if !f.alternate() => f.write_str(outermost),
            _ => {
                for frame in self.frames() {
                    write!(f, "{frame}: ")?;
                }
                write!(f, "{}", self.root)
            }
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            None => write!(f, "{:?}", self.root),
            Some(outermost) => {
                write!(f, "{outermost}")?;
                write!(f, "\n\nCaused by:")?;
                for frame in self.frames().skip(1) {
                    write!(f, "\n    {frame}")?;
                }
                write!(f, "\n    {}", self.root)
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

/// Dispatch helper behind [`Context`]: how an error value folds a
/// context frame into an [`Error`]. One impl for standard errors, one
/// for [`Error`] itself — the split that lets `.context(..)` work on
/// both `Result<T, io::Error>` and `Result<T, anyhow::Error>`.
mod ext {
    use super::*;

    pub trait StdError {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> StdError for E {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            Error::new(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`,
/// matching the real crate's semantics (an `Option` treats `None` as an
/// error made from the context message alone).
pub trait Context<T, E>: private::Sealed {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(context()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(context().to_string()))
    }
}

/// Construct an [`Error`] from a format string (with inline captures and
/// trailing arguments) or from any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = anyhow!("root {}", 7);
        assert_eq!(e.to_string(), "root 7");
        let e = Err::<(), _>(e).context("mid").unwrap_err();
        let e = Err::<(), _>(e).with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1");
        assert_eq!(format!("{e:#}"), "outer 1: mid: root 7");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn downcast_sees_through_context() {
        let r: Result<()> = Err(Error::new(Typed(3)));
        let e = r.context("wrapped").unwrap_err();
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(3)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "not a number".parse()?;
            Ok(n)
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn option_context_and_value_macro() {
        let none: Option<u32> = None;
        let e = none.context(format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        let e: Error = anyhow!(String::from("already built"));
        assert_eq!(e.to_string(), "already built");
    }
}
