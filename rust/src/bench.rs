//! Tiny benchmark harness (the vendor set has no criterion).
//!
//! `cargo bench` targets are `harness = false` binaries; they use this
//! module for warmup + repeated timing + summary statistics, printing
//! one `name: mean ± std (min..max, N)` line per case and returning the
//! samples for custom reporting.

use std::time::Instant;

use crate::util::Stats;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.stats.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter ± {:>10} (n={} x {})",
            self.name,
            crate::util::fmt_ns(self.stats.mean()),
            crate::util::fmt_ns(self.stats.stddev()),
            self.stats.len(),
            self.iters_per_sample,
        )
    }
}

/// Run `f` repeatedly: `warmup` unmeasured calls, then `samples` timed
/// samples of `iters` calls each. Reports per-iteration nanoseconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        stats.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    let r = BenchResult { name: name.to_string(), stats, iters_per_sample: iters };
    println!("{}", r.report());
    r
}

/// Convenience: auto-tune iteration count so one sample takes ≥ `target_ms`.
pub fn bench_auto<F: FnMut()>(name: &str, target_ms: f64, mut f: F) -> BenchResult {
    // Estimate cost with one call.
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target_ms * 1e6 / once_ns).ceil() as usize).clamp(1, 1_000_000);
    bench(name, 2.min(iters), 10, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let mut calls = 0u64;
        let r = bench("test", 1, 5, 3, || {
            calls += 1;
        });
        assert_eq!(calls, 1 + 5 * 3);
        assert_eq!(r.stats.len(), 5);
        assert!(r.mean_ns() >= 0.0);
    }

    #[test]
    fn bench_auto_runs() {
        let r = bench_auto("auto", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.stats.len() == 10);
    }

    #[test]
    fn report_contains_name() {
        let r = bench("xyz", 0, 2, 1, || {});
        assert!(r.report().contains("xyz"));
    }
}
