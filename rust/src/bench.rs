//! Tiny benchmark harness (the vendor set has no criterion).
//!
//! `cargo bench` targets are `harness = false` binaries; they use this
//! module for warmup + repeated timing + summary statistics, printing
//! one `name: mean ± std (min..max, N)` line per case and returning the
//! samples for custom reporting.

use std::time::Instant;

use crate::config::ModelShape;
use crate::lstm::model::InferenceState;
use crate::lstm::{BatchArena, LstmCellWeights, LstmModel};
use crate::tensor::Tensor;
use crate::util::{Rng, Stats};

/// Random weights for one LSTM layer, drawn from `rng` — the canonical
/// fixture shared by unit tests, benches and integration tests.
pub fn random_cell_weights(rng: &mut Rng, input_dim: usize, hidden: usize) -> LstmCellWeights {
    let wn = (input_dim + hidden) * 4 * hidden;
    let w: Vec<f32> = (0..wn).map(|_| rng.uniform(-0.2, 0.2)).collect();
    let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
    LstmCellWeights::new(
        Tensor::new(vec![input_dim + hidden, 4 * hidden], w),
        Tensor::new(vec![4 * hidden], b),
        input_dim,
        hidden,
    )
}

/// Deterministic random-weight [`LstmModel`] — the shared fixture for
/// benches and integration tests that must run without trained
/// artifacts (kernel/loop-structure comparisons, parity and chunking
/// properties). Same seed, same model, on every host.
pub fn random_model(shape: ModelShape, seed: u64) -> LstmModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut in_dim = shape.input_dim;
    for _ in 0..shape.num_layers {
        layers.push(random_cell_weights(&mut rng, in_dim, shape.hidden));
        in_dim = shape.hidden;
    }
    let w_out: Vec<f32> =
        (0..shape.hidden * shape.num_classes).map(|_| rng.uniform(-0.3, 0.3)).collect();
    LstmModel::new(
        shape,
        layers,
        Tensor::new(vec![shape.hidden, shape.num_classes], w_out),
        Tensor::new(vec![shape.num_classes], vec![0.0; shape.num_classes]),
    )
}

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.stats.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter ± {:>10} (n={} x {})",
            self.name,
            crate::util::fmt_ns(self.stats.mean()),
            crate::util::fmt_ns(self.stats.stddev()),
            self.stats.len(),
            self.iters_per_sample,
        )
    }
}

/// The per-row-GEMV vs batched-plan comparison at B ∈ {1, 2, 4, 8}
/// (EXPERIMENTS.md §Perf / A4), shared by the hotpath and ablations
/// benches so both always measure the identical fixture. Prints one
/// speedup line per batch size; returns the per-case results, per-row
/// then batched for each B.
pub fn bench_per_row_vs_batched(prefix: &str, target_ms: f64) -> Vec<BenchResult> {
    let shape = ModelShape::default();
    let model = random_model(shape, 42);
    let mut st = InferenceState::new(shape);
    let mut arena = BatchArena::with_capacity(shape, 8);
    let window_floats = shape.seq_len * shape.input_dim;
    let mut rng = Rng::new(9);
    let mut results = Vec::new();
    for &b in &[1usize, 2, 4, 8] {
        let data: Vec<f32> = (0..b * window_floats).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Tensor::new(vec![b, shape.seq_len, shape.input_dim], data);
        let per_row = bench_auto(&format!("{prefix}/native_per_row_b{b}"), target_ms, || {
            for i in 0..b {
                std::hint::black_box(model.forward_window(x.slab(i), &mut st));
            }
        });
        let batched = bench_auto(&format!("{prefix}/native_batched_b{b}"), target_ms, || {
            std::hint::black_box(model.forward_batch(&x, &mut arena));
        });
        println!(
            "{prefix}/native_batched_speedup_b{b}: {:.2}x",
            per_row.mean_ns() / batched.mean_ns()
        );
        results.push(per_row);
        results.push(batched);
    }
    results
}

/// The f32-batched vs int8-quantized comparison at B ∈ {1, 2, 4, 8}
/// (EXPERIMENTS.md §Perf quantization rows), shared by the hotpath and
/// ablations benches: same random-weight fixture and windows as
/// [`bench_per_row_vs_batched`], the quantized model packed once from
/// it. The f32 side is NOT re-timed: `f32_results` is the per-row
/// comparison's output, and each `native_quant_speedup_b{B}` line reads
/// the matching `native_batched_b{B}` case from it (identical fixture
/// and windows, so the ratio is like-for-like). Returns the quantized
/// cases.
pub fn bench_quant_vs_f32(
    prefix: &str,
    target_ms: f64,
    f32_results: &[BenchResult],
) -> Vec<BenchResult> {
    let shape = ModelShape::default();
    let qmodel = random_model(shape, 42).quantize();
    let mut arena = BatchArena::with_capacity(shape, 8);
    let window_floats = shape.seq_len * shape.input_dim;
    let mut rng = Rng::new(9);
    let mut results = Vec::new();
    for &b in &[1usize, 2, 4, 8] {
        let data: Vec<f32> = (0..b * window_floats).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Tensor::new(vec![b, shape.seq_len, shape.input_dim], data);
        let quant = bench_auto(&format!("{prefix}/native_quant_b{b}"), target_ms, || {
            std::hint::black_box(qmodel.forward_batch_quant(&x, &mut arena));
        });
        let reference = f32_results
            .iter()
            .find(|r| r.name.ends_with(&format!("/native_batched_b{b}")))
            .map(BenchResult::mean_ns);
        if let Some(f32_ns) = reference {
            println!(
                "{prefix}/native_quant_speedup_b{b}: {:.2}x",
                f32_ns / quant.mean_ns()
            );
        }
        results.push(quant);
    }
    results
}

/// Run `f` repeatedly: `warmup` unmeasured calls, then `samples` timed
/// samples of `iters` calls each. Reports per-iteration nanoseconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        stats.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    let r = BenchResult { name: name.to_string(), stats, iters_per_sample: iters };
    println!("{}", r.report());
    r
}

/// Convenience: auto-tune iteration count so one sample takes ≥ `target_ms`.
pub fn bench_auto<F: FnMut()>(name: &str, target_ms: f64, mut f: F) -> BenchResult {
    // Estimate cost with one call.
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target_ms * 1e6 / once_ns).ceil() as usize).clamp(1, 1_000_000);
    bench(name, 2.min(iters), 10, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let mut calls = 0u64;
        let r = bench("test", 1, 5, 3, || {
            calls += 1;
        });
        assert_eq!(calls, 1 + 5 * 3);
        assert_eq!(r.stats.len(), 5);
        assert!(r.mean_ns() >= 0.0);
    }

    #[test]
    fn bench_auto_runs() {
        let r = bench_auto("auto", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.stats.len() == 10);
    }

    #[test]
    fn report_contains_name() {
        let r = bench("xyz", 0, 2, 1, || {});
        assert!(r.report().contains("xyz"));
    }
}
