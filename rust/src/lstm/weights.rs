//! MRNW weight-container parser (format written by `python/compile/aot.py`).
//!
//! Layout (little-endian):
//! ```text
//! magic[4] "MRNW" | u32 version | u32 n_tensors
//! per tensor: u16 name_len | name | u8 ndim | u32 dims[ndim] | f32 data
//! ```

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::ModelShape;
use crate::lstm::cell::LstmCellWeights;
use crate::lstm::quant::{QuantizedCellWeights, QuantizedLstmModel};
use crate::tensor::Tensor;

/// A parsed MRNW file: named tensors in file order.
#[derive(Debug, Clone)]
pub struct WeightFile {
    pub names: Vec<String>,
    tensors: HashMap<String, Tensor>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

impl WeightFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let data = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&data).with_context(|| format!("parsing MRNW {path:?}"))
    }

    pub fn parse(mut data: &[u8]) -> Result<Self> {
        let r = &mut data;
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"MRNW" {
            return Err(anyhow!("bad magic {magic:?}"));
        }
        let version = read_u32(r)?;
        if version != 1 {
            return Err(anyhow!("unsupported MRNW version {version}"));
        }
        let n = read_u32(r)? as usize;
        let mut names = Vec::with_capacity(n);
        let mut tensors = HashMap::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u16(r)? as usize;
            let mut name_buf = vec![0u8; name_len];
            r.read_exact(&mut name_buf)?;
            let name = String::from_utf8(name_buf).context("tensor name not utf-8")?;
            let mut ndim_b = [0u8; 1];
            r.read_exact(&mut ndim_b)?;
            let ndim = ndim_b[0] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(r)? as usize);
            }
            let count: usize = dims.iter().product();
            let mut raw = vec![0u8; count * 4];
            r.read_exact(&mut raw)?;
            let vals: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name.clone(), Tensor::new(dims, vals));
            names.push(name);
        }
        if !r.is_empty() {
            return Err(anyhow!("{} trailing bytes after last tensor", r.len()));
        }
        Ok(Self { names, tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("tensor {name:?} missing (have {:?})", self.names))
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Tensors in file order — the exact order the AOT artifact's HLO
    /// parameters expect (after the leading `x` input).
    pub fn in_order(&self) -> Vec<&Tensor> {
        self.names.iter().map(|n| &self.tensors[n]).collect()
    }

    /// Interpret the file as stacked-LSTM weights for `shape`:
    /// layer{i}.w / layer{i}.b per layer, then head.w / head.b.
    pub fn to_model_weights(
        &self,
        shape: ModelShape,
    ) -> Result<(Vec<LstmCellWeights>, Tensor, Tensor)> {
        let mut layers = Vec::with_capacity(shape.num_layers);
        let mut in_dim = shape.input_dim;
        for li in 0..shape.num_layers {
            let w = self.get(&format!("layer{li}.w"))?.clone();
            let b = self.get(&format!("layer{li}.b"))?.clone();
            if w.shape() != [in_dim + shape.hidden, 4 * shape.hidden] {
                return Err(anyhow!(
                    "layer{li}.w shape {:?} != expected [{}, {}]",
                    w.shape(),
                    in_dim + shape.hidden,
                    4 * shape.hidden
                ));
            }
            layers.push(LstmCellWeights::new(w, b, in_dim, shape.hidden));
            in_dim = shape.hidden;
        }
        let w_out = self.get("head.w")?.clone();
        let b_out = self.get("head.b")?.clone();
        if w_out.shape() != [shape.hidden, shape.num_classes] {
            return Err(anyhow!("head.w shape {:?}", w_out.shape()));
        }
        Ok((layers, w_out, b_out))
    }

    /// The int8 pack step (DESIGN.md §10): interpret the file as
    /// stacked-LSTM weights for `shape` and quantize each layer's
    /// `[I+H, 4H]` matrix per output channel into the packed layout the
    /// integer GEMM runs on. Same shape validation as
    /// [`WeightFile::to_model_weights`]; the classifier head stays f32.
    pub fn to_quant_model_weights(&self, shape: ModelShape) -> Result<QuantizedLstmModel> {
        let (layers, w_out, b_out) = self.to_model_weights(shape)?;
        let qlayers = layers.iter().map(QuantizedCellWeights::quantize).collect();
        Ok(QuantizedLstmModel::new(shape, qlayers, w_out, b_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build an MRNW byte stream (mirrors the python writer).
    pub(crate) fn build_mrnw(entries: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"MRNW");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (name, dims, data) in entries {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(dims.len() as u8);
            for &d in *dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in *data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_roundtrip() {
        let bytes = build_mrnw(&[
            ("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            ("b.c", &[3], &[5.0, 6.0, 7.0]),
        ]);
        let wf = WeightFile::parse(&bytes).unwrap();
        assert_eq!(wf.names, vec!["a", "b.c"]);
        assert_eq!(wf.get("a").unwrap().shape(), &[2, 2]);
        assert_eq!(wf.get("b.c").unwrap().data(), &[5.0, 6.0, 7.0]);
        assert_eq!(wf.in_order().len(), 2);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(WeightFile::parse(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let mut bytes = build_mrnw(&[("a", &[1], &[0.0])]);
        bytes[4] = 9;
        assert!(WeightFile::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = build_mrnw(&[("a", &[1], &[0.0])]);
        bytes.push(0xFF);
        assert!(WeightFile::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = build_mrnw(&[("a", &[4], &[0.0; 4])]);
        assert!(WeightFile::parse(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn missing_tensor_error_lists_names() {
        let bytes = build_mrnw(&[("x", &[1], &[0.0])]);
        let wf = WeightFile::parse(&bytes).unwrap();
        let err = wf.get("y").unwrap_err().to_string();
        assert!(err.contains("\"x\""), "{err}");
    }

    #[test]
    fn to_model_weights_shape_check() {
        // A consistent tiny model: 1 layer, input 2, hidden 3, 2 classes.
        let shape = ModelShape {
            num_layers: 1,
            hidden: 3,
            input_dim: 2,
            seq_len: 4,
            num_classes: 2,
        };
        let w0 = vec![0.1f32; (2 + 3) * 12];
        let b0 = vec![0.0f32; 12];
        let hw = vec![0.2f32; 3 * 2];
        let hb = vec![0.0f32; 2];
        let bytes = build_mrnw(&[
            ("layer0.w", &[5, 12], &w0),
            ("layer0.b", &[12], &b0),
            ("head.w", &[3, 2], &hw),
            ("head.b", &[2], &hb),
        ]);
        let wf = WeightFile::parse(&bytes).unwrap();
        let (layers, w_out, _) = wf.to_model_weights(shape).unwrap();
        assert_eq!(layers.len(), 1);
        assert_eq!(w_out.shape(), &[3, 2]);

        // Wrong hidden size must be rejected.
        let bad = ModelShape { hidden: 4, ..shape };
        assert!(wf.to_model_weights(bad).is_err());

        // The quant pack step shares the validation and pads each GEMM
        // half's K to quads: [2, 12] -> [4, 12] and [3, 12] -> [4, 12]
        // int8, one scale per output channel per half.
        let qm = wf.to_quant_model_weights(shape).unwrap();
        assert_eq!(qm.layers().len(), 1);
        assert_eq!((qm.layers()[0].wx.k, qm.layers()[0].wx.k_padded), (2, 4));
        assert_eq!((qm.layers()[0].wh.k, qm.layers()[0].wh.k_padded), (3, 4));
        assert_eq!(qm.layers()[0].wx.scales.len(), 12);
        assert_eq!(qm.layers()[0].wh.scales.len(), 12);
        assert!(wf.to_quant_model_weights(bad).is_err());
    }
}
