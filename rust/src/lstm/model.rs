//! Stacked-LSTM classifier — the native (CPU) forward pass.
//!
//! Mirrors `python/compile/model.py::forward` + head. Two entry points:
//!
//! - [`LstmModel::forward_window`] — one window through per-row GEMVs
//!   with a reusable [`InferenceState`]. The B=1 specialization and the
//!   parity oracle for the batched plan.
//! - [`LstmModel::forward_batch`] / [`LstmModel::forward_rows`] — the
//!   whole batch advanced timestep-by-timestep through the time-major
//!   execution plan (`lstm::plan`, DESIGN.md §8), amortizing each
//!   weight-matrix traversal across batch rows.
//!
//! Both keep the paper's §3.2 discipline: state lives in a reusable
//! [`InferenceState`] / [`BatchArena`], so steady-state serving performs
//! ZERO heap allocations per inference beyond the logits buffer (see the
//! ablation bench `ablations.rs::mempool`).

use anyhow::Result;

use crate::config::ModelShape;
use crate::lstm::cell::{lstm_cell, CellScratch, LstmCellWeights};
use crate::lstm::plan::BatchArena;
use crate::lstm::quant::{QuantizedCellWeights, QuantizedLstmModel};
use crate::lstm::weights::WeightFile;
use crate::tensor::{argmax_slice, Tensor};

/// A loaded model: per-layer weights + classifier head.
#[derive(Debug, Clone)]
pub struct LstmModel {
    pub shape: ModelShape,
    layers: Vec<LstmCellWeights>,
    w_out: Tensor,
    b_out: Tensor,
}

/// Reusable per-worker inference state (paper §3.2 preallocation).
#[derive(Debug, Clone)]
pub struct InferenceState {
    h: Vec<Vec<f32>>,
    c: Vec<Vec<f32>>,
    scratch: CellScratch,
}

impl InferenceState {
    pub fn new(shape: ModelShape) -> Self {
        Self {
            h: vec![vec![0.0; shape.hidden]; shape.num_layers],
            c: vec![vec![0.0; shape.hidden]; shape.num_layers],
            scratch: CellScratch::new(shape.hidden),
        }
    }

    fn reset(&mut self) {
        for v in self.h.iter_mut().chain(self.c.iter_mut()) {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }
}

impl LstmModel {
    pub fn new(shape: ModelShape, layers: Vec<LstmCellWeights>, w_out: Tensor, b_out: Tensor) -> Self {
        assert_eq!(layers.len(), shape.num_layers);
        Self { shape, layers, w_out, b_out }
    }

    /// Load from an MRNW weight file.
    pub fn from_weight_file(shape: ModelShape, wf: &WeightFile) -> Result<Self> {
        let (layers, w_out, b_out) = wf.to_model_weights(shape)?;
        Ok(Self::new(shape, layers, w_out, b_out))
    }

    /// Classify one `[T, D]` window (flat slice, row-major). Returns logits.
    /// Allocation-free except the small logits vec.
    pub fn forward_window(&self, window: &[f32], state: &mut InferenceState) -> Vec<f32> {
        let s = self.shape;
        debug_assert_eq!(window.len(), s.seq_len * s.input_dim);
        state.reset();
        for t in 0..s.seq_len {
            let x = &window[t * s.input_dim..(t + 1) * s.input_dim];
            // First layer reads x; each next layer reads the previous
            // layer's fresh h. Split-borrow trick keeps it in-place.
            for li in 0..s.num_layers {
                if li == 0 {
                    lstm_cell(
                        &self.layers[0],
                        x,
                        &mut state.h[0],
                        &mut state.c[0],
                        &mut state.scratch,
                    );
                } else {
                    let (prev, cur) = state.h.split_at_mut(li);
                    lstm_cell(
                        &self.layers[li],
                        &prev[li - 1],
                        &mut cur[0],
                        &mut state.c[li],
                        &mut state.scratch,
                    );
                }
            }
        }
        // Head: logits = h_last @ W_out + b_out.
        let h_last = &state.h[s.num_layers - 1];
        let mut logits = self.b_out.data().to_vec();
        for (r, &hv) in h_last.iter().enumerate() {
            let row = self.w_out.row(r);
            for (l, wv) in logits.iter_mut().zip(row) {
                *l += hv * wv;
            }
        }
        logits
    }

    /// Classify a `[B, T, D]` batch tensor through the batched time-major
    /// plan; returns `[B, C]` logits, bit-for-bit equal to running each
    /// window through [`Self::forward_window`].
    pub fn forward_batch(&self, x: &Tensor, arena: &mut BatchArena) -> Tensor {
        let s = self.shape;
        assert_eq!(x.shape(), &[x.shape()[0], s.seq_len, s.input_dim]);
        let batch = x.shape()[0];
        let logits = self.forward_rows(x.data(), batch, arena);
        Tensor::new(vec![batch, s.num_classes], logits)
    }

    /// Classify `rows` windows given as flat `[rows, T, D]` data — the
    /// slice-level entry the threaded pool feeds contiguous sub-batch
    /// chunks through without copying. Returns flat `[rows, C]` logits.
    pub fn forward_rows(&self, windows: &[f32], rows: usize, arena: &mut BatchArena) -> Vec<f32> {
        let s = self.shape;
        assert_eq!(arena.shape(), s, "arena built for a different model shape");
        let h_last = arena.run(&self.layers, windows, rows);
        // Head per row: logits = h_last @ W_out + b_out, accumulated in
        // the same order as forward_window's head (bit-for-bit parity).
        let mut logits = vec![0.0f32; rows * s.num_classes];
        for (hrow, lrow) in
            h_last.chunks_exact(s.hidden).zip(logits.chunks_exact_mut(s.num_classes))
        {
            self.head_into(hrow, lrow);
        }
        logits
    }

    /// The classifier head for one `[H]` hidden row into one `[C]`
    /// logits row — the single accumulation-order-bearing implementation
    /// shared by the batched and streaming paths (bit-for-bit parity by
    /// construction).
    pub(crate) fn head_into(&self, hrow: &[f32], lrow: &mut [f32]) {
        lrow.copy_from_slice(self.b_out.data());
        for (r, &hv) in hrow.iter().enumerate() {
            for (l, wv) in lrow.iter_mut().zip(self.w_out.row(r)) {
                *l += hv * wv;
            }
        }
    }

    /// Per-layer cell weights, for the streaming driver (`lstm::stream`).
    pub(crate) fn cell_layers(&self) -> &[LstmCellWeights] {
        &self.layers
    }

    /// Predicted class for one window, under the crate-wide "first finite
    /// max" argmax rule ([`argmax_slice`]): NaN/±inf logits are skipped
    /// rather than panicking, an all-non-finite row maps to class 0.
    pub fn predict(&self, window: &[f32], state: &mut InferenceState) -> usize {
        argmax_slice(&self.forward_window(window, state))
    }

    /// Pack this model for the int8 quantized path (DESIGN.md §10):
    /// symmetric per-output-channel weight quantization per layer, head
    /// kept f32. One-time cost at load; the result drives
    /// [`QuantizedLstmModel::forward_batch_quant`].
    pub fn quantize(&self) -> QuantizedLstmModel {
        QuantizedLstmModel::new(
            self.shape,
            self.layers.iter().map(QuantizedCellWeights::quantize).collect(),
            self.w_out.clone(),
            self.b_out.clone(),
        )
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::Rng;

    pub(crate) fn random_model(shape: ModelShape, seed: u64) -> LstmModel {
        // The canonical fixture lives in bench.rs so benches and
        // integration tests share it; same seed -> same model.
        crate::bench::random_model(shape, seed)
    }

    fn tiny_shape() -> ModelShape {
        ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 10, num_classes: 4 }
    }

    #[test]
    fn forward_shapes() {
        let m = random_model(tiny_shape(), 1);
        let mut st = InferenceState::new(m.shape);
        let window = vec![0.1; 10 * 3];
        let logits = m.forward_window(&window, &mut st);
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_deterministic_and_state_isolated() {
        // Running window B after window A must give the same logits as
        // running B alone — InferenceState fully resets (no state leak
        // between requests, a serving-correctness invariant).
        let m = random_model(tiny_shape(), 2);
        let mut rng = Rng::new(3);
        let wa: Vec<f32> = (0..30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let wb: Vec<f32> = (0..30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut st = InferenceState::new(m.shape);
        let fresh = m.forward_window(&wb, &mut st.clone());
        m.forward_window(&wa, &mut st);
        let after_a = m.forward_window(&wb, &mut st);
        assert_eq!(fresh, after_a);
    }

    #[test]
    fn batch_equals_window_loop() {
        // The batched plan vs the per-window oracle, bit-for-bit.
        let m = random_model(tiny_shape(), 4);
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..3 * 30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Tensor::new(vec![3, 10, 3], data.clone());
        let mut arena = BatchArena::new(m.shape);
        let batch = m.forward_batch(&x, &mut arena);
        let mut st = InferenceState::new(m.shape);
        for i in 0..3 {
            let single = m.forward_window(&data[i * 30..(i + 1) * 30], &mut st);
            assert_eq!(batch.row(i), &single[..]);
        }
    }

    #[test]
    fn forward_rows_slices_match_batch() {
        // forward_rows over a sub-range of the flat data (the threaded
        // pool's chunk entry) must match the corresponding batch rows.
        let m = random_model(tiny_shape(), 9);
        let mut rng = Rng::new(10);
        let data: Vec<f32> = (0..5 * 30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Tensor::new(vec![5, 10, 3], data.clone());
        let mut arena = BatchArena::new(m.shape);
        let full = m.forward_batch(&x, &mut arena);
        let chunk = m.forward_rows(&data[2 * 30..5 * 30], 3, &mut arena);
        let c = m.shape.num_classes;
        for i in 0..3 {
            assert_eq!(full.row(2 + i), &chunk[i * c..(i + 1) * c]);
        }
    }

    #[test]
    fn predict_in_range() {
        let m = random_model(tiny_shape(), 6);
        let mut st = InferenceState::new(m.shape);
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let w: Vec<f32> = (0..30).map(|_| rng.uniform(-1.0, 1.0)).collect();
            assert!(m.predict(&w, &mut st) < 4);
        }
    }

    #[test]
    fn deeper_model_changes_output() {
        let s1 = ModelShape { num_layers: 1, ..tiny_shape() };
        let s2 = tiny_shape();
        let m1 = random_model(s1, 8);
        let m2 = random_model(s2, 8);
        let w = vec![0.5; 30];
        let l1 = m1.forward_window(&w, &mut InferenceState::new(s1));
        let l2 = m2.forward_window(&w, &mut InferenceState::new(s2));
        assert_ne!(l1, l2);
    }
}
