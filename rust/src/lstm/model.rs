//! Stacked-LSTM classifier — the native (CPU) forward pass.
//!
//! Mirrors `python/compile/model.py::forward` + head. The per-request
//! state (`h`/`c` per layer and the gate scratch) lives in a reusable
//! [`InferenceState`], so steady-state serving performs ZERO heap
//! allocations per inference — the Rust-CPU incarnation of the paper's
//! §3.2 "preallocate and reuse c/h" optimization (see the ablation bench
//! `ablations.rs::mempool`).

use anyhow::Result;

use crate::config::ModelShape;
use crate::lstm::cell::{lstm_cell, CellScratch, LstmCellWeights};
use crate::lstm::weights::WeightFile;
use crate::tensor::Tensor;

/// A loaded model: per-layer weights + classifier head.
#[derive(Debug, Clone)]
pub struct LstmModel {
    pub shape: ModelShape,
    layers: Vec<LstmCellWeights>,
    w_out: Tensor,
    b_out: Tensor,
}

/// Reusable per-worker inference state (paper §3.2 preallocation).
#[derive(Debug, Clone)]
pub struct InferenceState {
    h: Vec<Vec<f32>>,
    c: Vec<Vec<f32>>,
    scratch: CellScratch,
}

impl InferenceState {
    pub fn new(shape: ModelShape) -> Self {
        Self {
            h: vec![vec![0.0; shape.hidden]; shape.num_layers],
            c: vec![vec![0.0; shape.hidden]; shape.num_layers],
            scratch: CellScratch::new(shape.hidden),
        }
    }

    fn reset(&mut self) {
        for v in self.h.iter_mut().chain(self.c.iter_mut()) {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }
}

impl LstmModel {
    pub fn new(shape: ModelShape, layers: Vec<LstmCellWeights>, w_out: Tensor, b_out: Tensor) -> Self {
        assert_eq!(layers.len(), shape.num_layers);
        Self { shape, layers, w_out, b_out }
    }

    /// Load from an MRNW weight file.
    pub fn from_weight_file(shape: ModelShape, wf: &WeightFile) -> Result<Self> {
        let (layers, w_out, b_out) = wf.to_model_weights(shape)?;
        Ok(Self::new(shape, layers, w_out, b_out))
    }

    /// Classify one `[T, D]` window (flat slice, row-major). Returns logits.
    /// Allocation-free except the small logits vec.
    pub fn forward_window(&self, window: &[f32], state: &mut InferenceState) -> Vec<f32> {
        let s = self.shape;
        debug_assert_eq!(window.len(), s.seq_len * s.input_dim);
        state.reset();
        for t in 0..s.seq_len {
            let x = &window[t * s.input_dim..(t + 1) * s.input_dim];
            // First layer reads x; each next layer reads the previous
            // layer's fresh h. Split-borrow trick keeps it in-place.
            for li in 0..s.num_layers {
                if li == 0 {
                    lstm_cell(
                        &self.layers[0],
                        x,
                        &mut state.h[0],
                        &mut state.c[0],
                        &mut state.scratch,
                    );
                } else {
                    let (prev, cur) = state.h.split_at_mut(li);
                    lstm_cell(
                        &self.layers[li],
                        &prev[li - 1],
                        &mut cur[0],
                        &mut state.c[li],
                        &mut state.scratch,
                    );
                }
            }
        }
        // Head: logits = h_last @ W_out + b_out.
        let h_last = &state.h[s.num_layers - 1];
        let mut logits = self.b_out.data().to_vec();
        for (r, &hv) in h_last.iter().enumerate() {
            let row = self.w_out.row(r);
            for (l, wv) in logits.iter_mut().zip(row) {
                *l += hv * wv;
            }
        }
        logits
    }

    /// Classify a `[B, T, D]` batch tensor; returns `[B, C]` logits.
    pub fn forward_batch(&self, x: &Tensor, state: &mut InferenceState) -> Tensor {
        let s = self.shape;
        assert_eq!(x.shape(), &[x.shape()[0], s.seq_len, s.input_dim]);
        let batch = x.shape()[0];
        let mut out = Vec::with_capacity(batch * s.num_classes);
        for i in 0..batch {
            out.extend(self.forward_window(x.slab(i), state));
        }
        Tensor::new(vec![batch, s.num_classes], out)
    }

    /// Predicted class for one window.
    pub fn predict(&self, window: &[f32], state: &mut InferenceState) -> usize {
        let logits = self.forward_window(window, state);
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::Rng;

    pub(crate) fn random_model(shape: ModelShape, seed: u64) -> LstmModel {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let mut in_dim = shape.input_dim;
        for _ in 0..shape.num_layers {
            let wn = (in_dim + shape.hidden) * 4 * shape.hidden;
            let w: Vec<f32> = (0..wn).map(|_| rng.uniform(-0.2, 0.2)).collect();
            let b: Vec<f32> = (0..4 * shape.hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
            layers.push(LstmCellWeights::new(
                Tensor::new(vec![in_dim + shape.hidden, 4 * shape.hidden], w),
                Tensor::new(vec![4 * shape.hidden], b),
                in_dim,
                shape.hidden,
            ));
            in_dim = shape.hidden;
        }
        let w_out: Vec<f32> = (0..shape.hidden * shape.num_classes)
            .map(|_| rng.uniform(-0.3, 0.3))
            .collect();
        let b_out = vec![0.0; shape.num_classes];
        LstmModel::new(
            shape,
            layers,
            Tensor::new(vec![shape.hidden, shape.num_classes], w_out),
            Tensor::new(vec![shape.num_classes], b_out),
        )
    }

    fn tiny_shape() -> ModelShape {
        ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 10, num_classes: 4 }
    }

    #[test]
    fn forward_shapes() {
        let m = random_model(tiny_shape(), 1);
        let mut st = InferenceState::new(m.shape);
        let window = vec![0.1; 10 * 3];
        let logits = m.forward_window(&window, &mut st);
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_deterministic_and_state_isolated() {
        // Running window B after window A must give the same logits as
        // running B alone — InferenceState fully resets (no state leak
        // between requests, a serving-correctness invariant).
        let m = random_model(tiny_shape(), 2);
        let mut rng = Rng::new(3);
        let wa: Vec<f32> = (0..30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let wb: Vec<f32> = (0..30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut st = InferenceState::new(m.shape);
        let fresh = m.forward_window(&wb, &mut st.clone());
        m.forward_window(&wa, &mut st);
        let after_a = m.forward_window(&wb, &mut st);
        assert_eq!(fresh, after_a);
    }

    #[test]
    fn batch_equals_window_loop() {
        let m = random_model(tiny_shape(), 4);
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..3 * 30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Tensor::new(vec![3, 10, 3], data.clone());
        let mut st = InferenceState::new(m.shape);
        let batch = m.forward_batch(&x, &mut st);
        for i in 0..3 {
            let single = m.forward_window(&data[i * 30..(i + 1) * 30], &mut st);
            assert_eq!(batch.row(i), &single[..]);
        }
    }

    #[test]
    fn predict_in_range() {
        let m = random_model(tiny_shape(), 6);
        let mut st = InferenceState::new(m.shape);
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let w: Vec<f32> = (0..30).map(|_| rng.uniform(-1.0, 1.0)).collect();
            assert!(m.predict(&w, &mut st) < 4);
        }
    }

    #[test]
    fn deeper_model_changes_output() {
        let s1 = ModelShape { num_layers: 1, ..tiny_shape() };
        let s2 = tiny_shape();
        let m1 = random_model(s1, 8);
        let m2 = random_model(s2, 8);
        let w = vec![0.5; 30];
        let l1 = m1.forward_window(&w, &mut InferenceState::new(s1));
        let l2 = m2.forward_window(&w, &mut InferenceState::new(s2));
        assert_ne!(l1, l2);
    }
}
