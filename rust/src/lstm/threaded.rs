//! Multi-threaded CPU execution (paper §4.4).
//!
//! The paper's observation: when RenderScript's GPU driver is disabled,
//! the same data-parallel decomposition runs on CPU threads and captures
//! ≥70.5% of the GPU's benefit. Here the analogous design point is a
//! persistent worker pool that data-parallelizes a batch across threads
//! in contiguous SUB-BATCH CHUNKS — the paper's work-unit factorization
//! applied to the batch dimension. Each chunk advances through the
//! batched time-major plan (`lstm::plan`, DESIGN.md §8) on a worker that
//! owns its own preallocated [`BatchArena`] (the §3.2 buffer-reuse
//! discipline, per thread).
//!
//! Chunks index into ONE shared `Arc<Tensor>` of the whole batch —
//! rows are outermost in `[B, T, D]`, so a chunk is a contiguous slice
//! and no per-window copies happen (the old per-window jobs cloned every
//! window into its job).
//!
//! Wall-clock speedup on this 1-core CI image is obviously ~1×; the
//! *scaling* behaviour the paper measures is reproduced by the simulator
//! (`simulator::cpu`), which models per-core throughput and spawn
//! overhead. This module provides the real, correct parallel execution
//! path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::lstm::model::LstmModel;
use crate::lstm::plan::{chunk_spans, BatchArena};
use crate::tensor::Tensor;

enum Job {
    /// (first row, row count, shared [B, T, D] batch, result sender).
    /// Results are sent as (first row, flat [rows, C] logits).
    Chunk(usize, usize, Arc<Tensor>, mpsc::Sender<(usize, Vec<f32>)>),
    Shutdown,
}

/// Persistent worker pool over a shared [`LstmModel`].
pub struct ThreadedLstm {
    model: Arc<LstmModel>,
    tx: mpsc::Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    pub num_threads: usize,
    windows_done: Arc<AtomicUsize>,
}

impl ThreadedLstm {
    pub fn new(model: Arc<LstmModel>, num_threads: usize) -> Self {
        assert!(num_threads >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let windows_done = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(num_threads);
        for _ in 0..num_threads {
            let rx = Arc::clone(&rx);
            let model = Arc::clone(&model);
            let done = Arc::clone(&windows_done);
            workers.push(std::thread::spawn(move || {
                // One preallocated arena per worker, reused for every job.
                // Deliberately pool-less (no intra-batch `PlanPool`): this
                // dispatcher already saturates the socket across chunks,
                // and nesting row-partitioning inside each worker would
                // only oversubscribe cores.
                let mut arena = BatchArena::new(model.shape);
                let window_len = model.shape.seq_len * model.shape.input_dim;
                loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(Job::Chunk(start, rows, x, out)) => {
                            let data = &x.data()[start * window_len..(start + rows) * window_len];
                            let logits = model.forward_rows(data, rows, &mut arena);
                            done.fetch_add(rows, Ordering::Relaxed);
                            // Receiver may have gone away on cancel; fine.
                            let _ = out.send((start, logits));
                        }
                        Ok(Job::Shutdown) | Err(_) => break,
                    }
                }
            }));
        }
        Self { model, tx, workers, num_threads, windows_done }
    }

    /// Run a `[B, T, D]` batch across the pool; returns `[B, C]` logits in
    /// input order. Default chunking policy: `ceil(B / num_threads)` rows
    /// per chunk, so every worker gets at most one chunk per batch.
    /// The shared model, for callers that need a single-row entry point
    /// next to the pool (e.g. streaming sessions — one row gains nothing
    /// from fan-out).
    pub fn model(&self) -> &Arc<LstmModel> {
        &self.model
    }

    pub fn forward_batch(&self, x: &Tensor) -> Tensor {
        let batch = x.shape()[0];
        self.forward_batch_chunked(x, batch.div_ceil(self.num_threads).max(1))
    }

    /// Same, with an explicit chunk size (rows per job) — the chunking
    /// policy knob. Results are independent of `chunk_rows` (order and
    /// values; property-tested in `rust/tests/batched_plan.rs`).
    pub fn forward_batch_chunked(&self, x: &Tensor, chunk_rows: usize) -> Tensor {
        assert!(chunk_rows >= 1, "chunk_rows must be positive");
        let shape = self.model.shape;
        assert_eq!(
            &x.shape()[1..],
            &[shape.seq_len, shape.input_dim],
            "input must be [B, T, D] for this model"
        );
        let batch = x.shape()[0];
        // One clone of the whole batch shared by every chunk job (the
        // pool's threads outlive this borrow), instead of B per-window
        // copies.
        let shared = Arc::new(x.clone());
        let (otx, orx) = mpsc::channel();
        for (start, rows) in chunk_spans(batch, chunk_rows) {
            self.tx
                .send(Job::Chunk(start, rows, Arc::clone(&shared), otx.clone()))
                .expect("worker pool alive");
        }
        drop(otx);
        let mut out = vec![0.0f32; batch * shape.num_classes];
        let mut received = 0;
        for (start, logits) in orx {
            received += logits.len() / shape.num_classes;
            out[start * shape.num_classes..start * shape.num_classes + logits.len()]
                .copy_from_slice(&logits);
        }
        assert_eq!(received, batch, "every chunk completed");
        Tensor::new(vec![batch, shape.num_classes], out)
    }

    /// Total windows (batch rows) completed by all workers since
    /// construction.
    pub fn windows_completed(&self) -> usize {
        self.windows_done.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadedLstm {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::lstm::model::tests::random_model;
    use crate::util::Rng;

    fn tiny() -> (Arc<LstmModel>, Tensor) {
        let shape = ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 10, num_classes: 4 };
        let model = Arc::new(random_model(shape, 42));
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..7 * 30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        (model, Tensor::new(vec![7, 10, 3], data))
    }

    #[test]
    fn threaded_matches_single() {
        let (model, x) = tiny();
        let mut arena = BatchArena::new(model.shape);
        let expected = model.forward_batch(&x, &mut arena);
        for threads in [1, 2, 4] {
            let pool = ThreadedLstm::new(Arc::clone(&model), threads);
            let got = pool.forward_batch(&x);
            assert_eq!(got, expected, "threads={threads}");
        }
    }

    #[test]
    fn chunk_size_does_not_change_results() {
        let (model, x) = tiny();
        let mut arena = BatchArena::new(model.shape);
        let expected = model.forward_batch(&x, &mut arena);
        let pool = ThreadedLstm::new(Arc::clone(&model), 3);
        for chunk in 1..=8 {
            let got = pool.forward_batch_chunked(&x, chunk);
            assert_eq!(got, expected, "chunk={chunk}");
        }
    }

    #[test]
    fn preserves_input_order() {
        // Distinct windows -> distinct logits; order must be input order.
        let (model, x) = tiny();
        let pool = ThreadedLstm::new(Arc::clone(&model), 3);
        let out1 = pool.forward_batch(&x);
        let out2 = pool.forward_batch(&x);
        assert_eq!(out1, out2);
    }

    #[test]
    fn pool_reusable_across_batches() {
        let (model, x) = tiny();
        let pool = ThreadedLstm::new(model, 2);
        for _ in 0..5 {
            let _ = pool.forward_batch(&x);
        }
        assert_eq!(pool.windows_completed(), 5 * 7);
    }

    #[test]
    fn shutdown_on_drop_is_clean() {
        let (model, x) = tiny();
        let pool = ThreadedLstm::new(model, 4);
        let _ = pool.forward_batch(&x);
        drop(pool); // must not hang or panic
    }
}
