//! Multi-threaded CPU execution (paper §4.4).
//!
//! The paper's observation: when RenderScript's GPU driver is disabled,
//! the same data-parallel decomposition runs on CPU threads and captures
//! ≥70.5% of the GPU's benefit. Here the analogous design point is a
//! persistent worker pool that data-parallelizes a batch of windows
//! across threads, each worker owning its own preallocated
//! [`InferenceState`] (the §3.2 buffer-reuse discipline, per thread).
//!
//! Wall-clock speedup on this 1-core CI image is obviously ~1×; the
//! *scaling* behaviour the paper measures is reproduced by the simulator
//! (`simulator::cpu`), which models per-core throughput and spawn
//! overhead. This module provides the real, correct parallel execution
//! path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::lstm::model::{InferenceState, LstmModel};
use crate::tensor::Tensor;

enum Job {
    /// (window index, flat [T*D] data, result slot sender)
    Window(usize, Vec<f32>, mpsc::Sender<(usize, Vec<f32>)>),
    Shutdown,
}

/// Persistent worker pool over a shared [`LstmModel`].
pub struct ThreadedLstm {
    model: Arc<LstmModel>,
    tx: mpsc::Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    pub num_threads: usize,
    jobs_done: Arc<AtomicUsize>,
}

impl ThreadedLstm {
    pub fn new(model: Arc<LstmModel>, num_threads: usize) -> Self {
        assert!(num_threads >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let jobs_done = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(num_threads);
        for _ in 0..num_threads {
            let rx = Arc::clone(&rx);
            let model = Arc::clone(&model);
            let done = Arc::clone(&jobs_done);
            workers.push(std::thread::spawn(move || {
                // One preallocated state per worker, reused for every job.
                let mut state = InferenceState::new(model.shape);
                loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(Job::Window(idx, data, out)) => {
                            let logits = model.forward_window(&data, &mut state);
                            done.fetch_add(1, Ordering::Relaxed);
                            // Receiver may have gone away on cancel; fine.
                            let _ = out.send((idx, logits));
                        }
                        Ok(Job::Shutdown) | Err(_) => break,
                    }
                }
            }));
        }
        Self { model, tx, workers, num_threads, jobs_done }
    }

    /// Run a `[B, T, D]` batch across the pool; returns `[B, C]` logits in
    /// input order.
    pub fn forward_batch(&self, x: &Tensor) -> Tensor {
        let shape = self.model.shape;
        let batch = x.shape()[0];
        let (otx, orx) = mpsc::channel();
        for i in 0..batch {
            self.tx
                .send(Job::Window(i, x.slab(i).to_vec(), otx.clone()))
                .expect("worker pool alive");
        }
        drop(otx);
        let mut rows: Vec<Option<Vec<f32>>> = vec![None; batch];
        for (idx, logits) in orx {
            rows[idx] = Some(logits);
        }
        let mut out = Vec::with_capacity(batch * shape.num_classes);
        for row in rows {
            out.extend(row.expect("every window completed"));
        }
        Tensor::new(vec![batch, shape.num_classes], out)
    }

    /// Total jobs completed by all workers since construction.
    pub fn jobs_completed(&self) -> usize {
        self.jobs_done.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadedLstm {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::lstm::model::tests::random_model;
    use crate::util::Rng;

    fn tiny() -> (Arc<LstmModel>, Tensor) {
        let shape = ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 10, num_classes: 4 };
        let model = Arc::new(random_model(shape, 42));
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..7 * 30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        (model, Tensor::new(vec![7, 10, 3], data))
    }

    #[test]
    fn threaded_matches_single() {
        let (model, x) = tiny();
        let mut st = InferenceState::new(model.shape);
        let expected = model.forward_batch(&x, &mut st);
        for threads in [1, 2, 4] {
            let pool = ThreadedLstm::new(Arc::clone(&model), threads);
            let got = pool.forward_batch(&x);
            assert_eq!(got, expected, "threads={threads}");
        }
    }

    #[test]
    fn preserves_input_order() {
        // Distinct windows -> distinct logits; order must be input order.
        let (model, x) = tiny();
        let pool = ThreadedLstm::new(Arc::clone(&model), 3);
        let out1 = pool.forward_batch(&x);
        let out2 = pool.forward_batch(&x);
        assert_eq!(out1, out2);
    }

    #[test]
    fn pool_reusable_across_batches() {
        let (model, x) = tiny();
        let pool = ThreadedLstm::new(model, 2);
        for _ in 0..5 {
            let _ = pool.forward_batch(&x);
        }
        assert_eq!(pool.jobs_completed(), 5 * 7);
    }

    #[test]
    fn shutdown_on_drop_is_clean() {
        let (model, x) = tiny();
        let pool = ThreadedLstm::new(model, 4);
        let _ = pool.forward_batch(&x);
        drop(pool); // must not hang or panic
    }
}
