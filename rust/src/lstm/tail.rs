//! The fused LSTM gate tail — the `(i, g, f, o) → c', h'` point-wise
//! update — as a dispatched kernel (DESIGN.md §14).
//!
//! After the SIMD GEMMs (DESIGN.md §13) the scalar libm `sigmoid`/`tanh`
//! tail became the dominant share of f32 per-step time (EXPERIMENTS.md
//! §Perf, Amdahl note). This module gives the tail the same treatment
//! the GEMMs got: one entry in the [`crate::kernel::dispatch`] table,
//! three implementations, one accuracy contract shared by every
//! consumer — `plan::step_rows` (batched + `PlanPool` row partitions),
//! `quant::step_rows_quant` (the f32 requantized tail of the int8
//! tier), the streaming path (which drives both at `rows = 1`), and the
//! B=1 oracle `cell::lstm_cell`:
//!
//! - **scalar** — [`lstm_tail_scalar`]: the original libm tail,
//!   verbatim. This is the parity oracle, and under
//!   `MOBIRNN_FORCE_SCALAR`/`--force-scalar` it is what the whole
//!   process runs — including the int8 tier, which previously used the
//!   scalar Padé tail unconditionally.
//! - **AVX2 / NEON** — `simd::lstm_tail_avx2` / `simd::lstm_tail_neon`:
//!   the full gate update per 8/4-lane block on a clamped Padé (5,4)
//!   vector `tanh` (σ derived as `0.5 + 0.5·tanh(x/2)`), i.e. the int8
//!   tier's [`fast_tanh`]/[`fast_sigmoid`] vectorized.
//!
//! # Bit-parity by construction (why no FMA)
//!
//! The vector kernels use only `mul`/`add`/`div`/`min`/`max` — **no
//! fused multiply-add anywhere** — in exactly the operation order of the
//! scalar [`fast_tanh`]/[`fast_sigmoid`] chain and of the
//! [`gate_update`] expression. Every IEEE-754 op then rounds identically
//! lane-by-lane, so:
//!
//! - vector lanes ≡ the scalar Padé helpers bit-for-bit, which makes the
//!   `hid % 8` (resp. `% 4`) remainder — handled one element at a time
//!   on the scalar helpers — indistinguishable from the vector lanes;
//! - the int8 tier's numerics on SIMD hosts are **unchanged** by this
//!   refactor: its old scalar Padé loop and the new vector tail produce
//!   the same bits;
//! - the batched/pooled/streaming bit-for-bit parity contracts survive
//!   untouched: the tail is per-element with a fixed per-row layout, so
//!   any row partitioning or chunking visits the identical chain.
//!
//! The tail costs ~5 rational evaluations per element; the FMA we give
//! up is a few percent of that — determinism is worth more here than
//! one fused rounding.
//!
//! # Error bound (why Padé is safe for argmax parity)
//!
//! Component bounds (dense-sweep-asserted in `rust/tests/quant.rs`):
//! `|fast_tanh − tanh| < 1.5e-3`, `|fast_sigmoid − σ| < 8e-4` on
//! [-10, 10]. Propagating through one fused update with `|c| ≤ C`:
//!
//! ```text
//! |Δc'| ≤ Δσ·C + (Δσ·1 + 1·Δtanh)        ≤ 8e-4·C + 2.3e-3
//! |Δh'| ≤ Δσ·1 + 1·(Δtanh + |Δc'|)       (|tanh'| ≤ 1, σ ≤ 1)
//! ```
//!
//! giving [`TAIL_C_MAX_ABS_ERR`] = 5e-3 and [`TAIL_H_MAX_ABS_ERR`] =
//! 8e-3 for `|c| ≤ 2` — the regime trained classifiers inhabit (the
//! forget gate is < 1, so c is a geometric sum of tanh outputs). The
//! per-step h error does not compound: the recurrence is contractive on
//! the parity fixtures (see `rust/tests/quant.rs` module docs), and the
//! classifier head's logit margins are orders of magnitude above 8e-3,
//! which is why ≥ 99% argmax parity vs the libm oracle holds end to end
//! (`rust/tests/tail.rs`). The same argument already carried the int8
//! tier, whose perturbation (quantization + this tail) is strictly
//! larger.

use crate::lstm::cell::{sigmoid, FORGET_BIAS};

/// Documented bound: `|fast_tanh(x) - tanh(x)| < 1.5e-3` on [-10, 10].
/// The true maximum is ≈ 1.07e-3, at the ±3.5 clamp boundary.
pub const TANH_MAX_ABS_ERR: f32 = 1.5e-3;

/// Documented bound: `|fast_sigmoid(x) - σ(x)| < 8e-4` on [-10, 10]
/// (half the tanh bound, since σ(x) = (1 + tanh(x/2)) / 2).
pub const SIGMOID_MAX_ABS_ERR: f32 = 8.0e-4;

/// Fused-tail bound on the cell state: `|c'_pade − c'_libm| ≤ 5e-3` for
/// gate pre-activations in [-10, 10] and `|c| ≤ 2` (module docs have the
/// derivation). Dense-sweep-asserted in `rust/tests/tail.rs`.
pub const TAIL_C_MAX_ABS_ERR: f32 = 5.0e-3;

/// Fused-tail bound on the hidden state under the same conditions:
/// `|h'_pade − h'_libm| ≤ 8e-3`.
pub const TAIL_H_MAX_ABS_ERR: f32 = 8.0e-3;

/// Fast `tanh`: the Padé (5,4) truncation of the continued fraction
/// `x/(1+x²/(3+x²/(5+x²/(7+x²/9))))`, input-clamped to ±3.5 where the
/// rational part reads 0.999239 (true tanh: 0.998178). Branch-free and
/// division-for-exp, so the point-wise tail vectorizes; max abs error
/// ≈ 1.07e-3 at the clamp (see [`TANH_MAX_ABS_ERR`]), monotone
/// non-decreasing, saturating at ±0.999239. The vector kernels in
/// [`simd`] replay this exact op chain 8/4 lanes at a time.
#[inline(always)]
pub fn fast_tanh(x: f32) -> f32 {
    let x = x.clamp(-3.5, 3.5);
    let x2 = x * x;
    let p = x * (945.0 + x2 * (105.0 + x2));
    let q = 945.0 + x2 * (420.0 + 15.0 * x2);
    p / q
}

/// Fast logistic via [`fast_tanh`]: `σ(x) = (1 + tanh(x/2)) / 2`.
/// Max abs error ≈ 5.4e-4 (see [`SIGMOID_MAX_ABS_ERR`]); monotone
/// non-decreasing; saturates at 3.8e-4 / 0.99962 beyond |x| = 7.
#[inline(always)]
pub fn fast_sigmoid(x: f32) -> f32 {
    0.5 + 0.5 * fast_tanh(0.5 * x)
}

/// THE gate-update expression — `c' = σ(f + bias)·c + σ(i)·tanh(g)`,
/// `h' = σ(o)·tanh(c')` — written exactly once, parameterized over the
/// σ/tanh pair. Every scalar tail (libm oracle, Padé, the vector
/// kernels' remainder lanes) instantiates this one expression, so the
/// oracle cannot drift from itself across its call sites (plan, quant,
/// stream, cell all route here through [`lstm_tail`]).
#[inline(always)]
pub(crate) fn gate_update<S, T>(i: f32, g: f32, f: f32, o: f32, c: f32, sig: S, th: T) -> (f32, f32)
where
    S: Fn(f32) -> f32,
    T: Fn(f32) -> f32,
{
    let c_next = sig(f + FORGET_BIAS) * c + sig(i) * th(g);
    let h_next = sig(o) * th(c_next);
    (c_next, h_next)
}

/// [`gate_update`] on the libm pair — one element of the exact oracle.
#[inline(always)]
fn libm_update(i: f32, g: f32, f: f32, o: f32, c: f32) -> (f32, f32) {
    gate_update(i, g, f, o, c, sigmoid, f32::tanh)
}

/// [`gate_update`] on the Padé pair — one element of the approximate
/// tail; the vector kernels' remainder path (bit-equal to their lanes).
#[inline(always)]
pub(crate) fn pade_update(i: f32, g: f32, f: f32, o: f32, c: f32) -> (f32, f32) {
    gate_update(i, g, f, o, c, fast_sigmoid, fast_tanh)
}

/// Shared row walk: apply `update` to every `(gates row, h row, c row)`
/// triple. `gates` is `[rows, 4H]` in (i, g, f, o) quarter layout;
/// `h`/`c` are `[rows, H]`, overwritten in place.
#[inline(always)]
fn tail_rows(
    gates: &[f32],
    h: &mut [f32],
    c: &mut [f32],
    rows: usize,
    hid: usize,
    update: fn(f32, f32, f32, f32, f32) -> (f32, f32),
) {
    debug_assert!(gates.len() >= rows * 4 * hid);
    debug_assert_eq!(h.len(), rows * hid);
    debug_assert_eq!(c.len(), rows * hid);
    for ((grow, hrow), crow) in gates[..rows * 4 * hid]
        .chunks_exact(4 * hid)
        .zip(h.chunks_exact_mut(hid))
        .zip(c.chunks_exact_mut(hid))
    {
        let (ig, rest) = grow.split_at(hid);
        let (gg, rest) = rest.split_at(hid);
        let (fg, og) = rest.split_at(hid);
        for k in 0..hid {
            let (cn, hn) = update(ig[k], gg[k], fg[k], og[k], crow[k]);
            crow[k] = cn;
            hrow[k] = hn;
        }
    }
}

/// The libm scalar tail — the parity oracle, verbatim the tail every
/// consumer ran before the dispatch table grew this entry. Selected by
/// the scalar ISA (`MOBIRNN_FORCE_SCALAR` / `--force-scalar`).
pub fn lstm_tail_scalar(gates: &[f32], h: &mut [f32], c: &mut [f32], rows: usize, hid: usize) {
    tail_rows(gates, h, c, rows, hid, libm_update);
}

/// The scalar Padé tail — [`lstm_tail_scalar`]'s shape on
/// [`fast_sigmoid`]/[`fast_tanh`]. Bit-identical to the vector kernels
/// (module docs); exposed for the tail microbench and the parity tests.
pub fn lstm_tail_pade_scalar(gates: &[f32], h: &mut [f32], c: &mut [f32], rows: usize, hid: usize) {
    tail_rows(gates, h, c, rows, hid, pade_update);
}

/// The process-wide fused tail: one relaxed load + indirect call through
/// [`crate::kernel::dispatch`]. This is the ONLY tail entry the LSTM
/// consumers (plan/quant/stream/cell) call.
#[inline]
pub fn lstm_tail(gates: &[f32], h: &mut [f32], c: &mut [f32], rows: usize, hid: usize) {
    (crate::kernel::dispatch().lstm_tail_f32)(gates, h, c, rows, hid)
}

/// AVX2 fused tail (x86_64). Structure mirrors `tensor::simd`: a safe
/// shape-checked wrapper over a `#[target_feature]` body; 8-lane blocks
/// over each row's H, scalar-Padé remainder (bit-equal to the lanes —
/// module docs).
#[cfg(target_arch = "x86_64")]
pub(crate) mod simd {
    use std::arch::x86_64::*;

    use crate::lstm::cell::FORGET_BIAS;

    pub(crate) fn lstm_tail_avx2(
        gates: &[f32],
        h: &mut [f32],
        c: &mut [f32],
        rows: usize,
        hid: usize,
    ) {
        debug_assert!(gates.len() >= rows * 4 * hid);
        debug_assert_eq!(h.len(), rows * hid);
        debug_assert_eq!(c.len(), rows * hid);
        // SAFETY: only reachable through the dispatch table after AVX2
        // was detected; the shape asserts bound every pointer offset.
        unsafe { tail_avx2(gates.as_ptr(), h.as_mut_ptr(), c.as_mut_ptr(), rows, hid) }
    }

    /// # Safety
    /// Requires AVX2; `gates` valid for `rows*4*hid` f32 reads, `h`/`c`
    /// for `rows*hid` f32 reads and writes.
    #[target_feature(enable = "avx2")]
    unsafe fn tail_avx2(gates: *const f32, h: *mut f32, c: *mut f32, rows: usize, hid: usize) {
        unsafe {
            for r in 0..rows {
                let g0 = gates.add(r * 4 * hid);
                let (ig, gg) = (g0, g0.add(hid));
                let (fg, og) = (g0.add(2 * hid), g0.add(3 * hid));
                let hrow = h.add(r * hid);
                let crow = c.add(r * hid);
                let mut k = 0;
                while k + 8 <= hid {
                    let i = sigmoid8(_mm256_loadu_ps(ig.add(k)));
                    let g = tanh8(_mm256_loadu_ps(gg.add(k)));
                    let f = sigmoid8(_mm256_add_ps(
                        _mm256_loadu_ps(fg.add(k)),
                        _mm256_set1_ps(FORGET_BIAS),
                    ));
                    let o = sigmoid8(_mm256_loadu_ps(og.add(k)));
                    // mul + add, NOT fmadd: each lane's chain must equal
                    // the scalar Padé helpers bit for bit (module docs).
                    let fc = _mm256_mul_ps(f, _mm256_loadu_ps(crow.add(k)));
                    let c_next = _mm256_add_ps(fc, _mm256_mul_ps(i, g));
                    _mm256_storeu_ps(crow.add(k), c_next);
                    _mm256_storeu_ps(hrow.add(k), _mm256_mul_ps(o, tanh8(c_next)));
                    k += 8;
                }
                while k < hid {
                    let (cn, hn) = super::pade_update(
                        *ig.add(k),
                        *gg.add(k),
                        *fg.add(k),
                        *og.add(k),
                        *crow.add(k),
                    );
                    *crow.add(k) = cn;
                    *hrow.add(k) = hn;
                    k += 1;
                }
            }
        }
    }

    /// Vector Padé (5,4) tanh — `fast_tanh`'s exact op chain, 8 lanes.
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn tanh8(x: __m256) -> __m256 {
        unsafe {
            let x = _mm256_min_ps(_mm256_max_ps(x, _mm256_set1_ps(-3.5)), _mm256_set1_ps(3.5));
            let x2 = _mm256_mul_ps(x, x);
            // p = x·(945 + x2·(105 + x2)); q = 945 + x2·(420 + 15·x2) —
            // the scalar chain's exact ops, one named temp per factor.
            let p_in = _mm256_mul_ps(x2, _mm256_add_ps(_mm256_set1_ps(105.0), x2));
            let p = _mm256_mul_ps(x, _mm256_add_ps(_mm256_set1_ps(945.0), p_in));
            let t15 = _mm256_mul_ps(_mm256_set1_ps(15.0), x2);
            let q_in = _mm256_mul_ps(x2, _mm256_add_ps(_mm256_set1_ps(420.0), t15));
            let q = _mm256_add_ps(_mm256_set1_ps(945.0), q_in);
            _mm256_div_ps(p, q)
        }
    }

    /// Vector logistic — `fast_sigmoid`'s exact op chain, 8 lanes.
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn sigmoid8(x: __m256) -> __m256 {
        unsafe {
            let half = _mm256_set1_ps(0.5);
            _mm256_add_ps(half, _mm256_mul_ps(half, tanh8(_mm256_mul_ps(half, x))))
        }
    }
}

/// NEON fused tail (aarch64 baseline) — the AVX2 kernel's structure at
/// 4 lanes, same no-FMA discipline, same scalar-Padé remainder.
#[cfg(target_arch = "aarch64")]
pub(crate) mod simd {
    use std::arch::aarch64::*;

    use crate::lstm::cell::FORGET_BIAS;

    pub(crate) fn lstm_tail_neon(
        gates: &[f32],
        h: &mut [f32],
        c: &mut [f32],
        rows: usize,
        hid: usize,
    ) {
        debug_assert!(gates.len() >= rows * 4 * hid);
        debug_assert_eq!(h.len(), rows * hid);
        debug_assert_eq!(c.len(), rows * hid);
        // SAFETY: NEON is architecturally guaranteed on aarch64; the
        // shape asserts bound every pointer offset used inside.
        unsafe { tail_neon(gates.as_ptr(), h.as_mut_ptr(), c.as_mut_ptr(), rows, hid) }
    }

    /// # Safety
    /// `gates` valid for `rows*4*hid` f32 reads, `h`/`c` for `rows*hid`
    /// f32 reads and writes.
    #[target_feature(enable = "neon")]
    unsafe fn tail_neon(gates: *const f32, h: *mut f32, c: *mut f32, rows: usize, hid: usize) {
        unsafe {
            for r in 0..rows {
                let g0 = gates.add(r * 4 * hid);
                let (ig, gg) = (g0, g0.add(hid));
                let (fg, og) = (g0.add(2 * hid), g0.add(3 * hid));
                let hrow = h.add(r * hid);
                let crow = c.add(r * hid);
                let mut k = 0;
                while k + 4 <= hid {
                    let i = sigmoid4(vld1q_f32(ig.add(k)));
                    let g = tanh4(vld1q_f32(gg.add(k)));
                    let f = sigmoid4(vaddq_f32(vld1q_f32(fg.add(k)), vdupq_n_f32(FORGET_BIAS)));
                    let o = sigmoid4(vld1q_f32(og.add(k)));
                    // mul + add, NOT vfmaq: lane chain ≡ scalar Padé.
                    let fc = vmulq_f32(f, vld1q_f32(crow.add(k)));
                    let c_next = vaddq_f32(fc, vmulq_f32(i, g));
                    vst1q_f32(crow.add(k), c_next);
                    vst1q_f32(hrow.add(k), vmulq_f32(o, tanh4(c_next)));
                    k += 4;
                }
                while k < hid {
                    let (cn, hn) = super::pade_update(
                        *ig.add(k),
                        *gg.add(k),
                        *fg.add(k),
                        *og.add(k),
                        *crow.add(k),
                    );
                    *crow.add(k) = cn;
                    *hrow.add(k) = hn;
                    k += 1;
                }
            }
        }
    }

    /// Vector Padé (5,4) tanh — `fast_tanh`'s exact op chain, 4 lanes.
    ///
    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn tanh4(x: float32x4_t) -> float32x4_t {
        unsafe {
            let x = vminq_f32(vmaxq_f32(x, vdupq_n_f32(-3.5)), vdupq_n_f32(3.5));
            let x2 = vmulq_f32(x, x);
            // Same factor naming as `tanh8` — the scalar chain's exact ops.
            let p_in = vmulq_f32(x2, vaddq_f32(vdupq_n_f32(105.0), x2));
            let p = vmulq_f32(x, vaddq_f32(vdupq_n_f32(945.0), p_in));
            let t15 = vmulq_f32(vdupq_n_f32(15.0), x2);
            let q_in = vmulq_f32(x2, vaddq_f32(vdupq_n_f32(420.0), t15));
            let q = vaddq_f32(vdupq_n_f32(945.0), q_in);
            vdivq_f32(p, q)
        }
    }

    /// Vector logistic — `fast_sigmoid`'s exact op chain, 4 lanes.
    ///
    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn sigmoid4(x: float32x4_t) -> float32x4_t {
        unsafe {
            let half = vdupq_n_f32(0.5);
            vaddq_f32(half, vmulq_f32(half, tanh4(vmulq_f32(half, x))))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_tail_case(rng: &mut Rng, rows: usize, hid: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let gates: Vec<f32> = (0..rows * 4 * hid).map(|_| rng.uniform(-6.0, 6.0)).collect();
        let h: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.5, 1.5)).collect();
        (gates, h, c)
    }

    #[test]
    fn scalar_tails_instantiate_the_shared_gate_update() {
        // Both scalar kernels must equal a hand-unrolled gate_update walk
        // exactly — the satellite contract that the oracle expression
        // exists once.
        let mut rng = Rng::new(5);
        for &(rows, hid) in &[(1usize, 7usize), (3, 8), (2, 33)] {
            let (gates, h0, c0) = random_tail_case(&mut rng, rows, hid);
            for (tail, upd) in [
                (
                    lstm_tail_scalar as fn(&[f32], &mut [f32], &mut [f32], usize, usize),
                    libm_update as fn(f32, f32, f32, f32, f32) -> (f32, f32),
                ),
                (lstm_tail_pade_scalar, pade_update),
            ] {
                let (mut h, mut c) = (h0.clone(), c0.clone());
                tail(&gates, &mut h, &mut c, rows, hid);
                for r in 0..rows {
                    for k in 0..hid {
                        let g0 = r * 4 * hid;
                        let (cn, hn) = upd(
                            gates[g0 + k],
                            gates[g0 + hid + k],
                            gates[g0 + 2 * hid + k],
                            gates[g0 + 3 * hid + k],
                            c0[r * hid + k],
                        );
                        assert_eq!(c[r * hid + k].to_bits(), cn.to_bits(), "c[{r},{k}]");
                        assert_eq!(h[r * hid + k].to_bits(), hn.to_bits(), "h[{r},{k}]");
                    }
                }
            }
        }
    }

    #[test]
    fn dispatched_tail_bit_equal_to_its_scalar_reference() {
        // The no-FMA construction makes the dispatched tail bit-identical
        // to a scalar reference on EVERY host: the libm oracle under the
        // scalar ISA, the scalar Padé chain under AVX2/NEON (lanes AND
        // the hid % lane-width remainder).
        let reference: fn(&[f32], &mut [f32], &mut [f32], usize, usize) =
            if crate::kernel::active() == crate::kernel::KernelIsa::Scalar {
                lstm_tail_scalar
            } else {
                lstm_tail_pade_scalar
            };
        let mut rng = Rng::new(17);
        for &(rows, hid) in &[(1usize, 1usize), (1, 5), (3, 8), (2, 13), (4, 32), (1, 37)] {
            let (gates, h0, c0) = random_tail_case(&mut rng, rows, hid);
            let (mut h, mut c) = (h0.clone(), c0.clone());
            let (mut h_ref, mut c_ref) = (h0.clone(), c0.clone());
            lstm_tail(&gates, &mut h, &mut c, rows, hid);
            reference(&gates, &mut h_ref, &mut c_ref, rows, hid);
            for (a, b) in h.iter().zip(&h_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "h rows={rows} hid={hid}");
            }
            for (a, b) in c.iter().zip(&c_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "c rows={rows} hid={hid}");
            }
        }
    }

    #[test]
    fn pade_tail_within_fused_bounds_of_libm() {
        // The fused-output bounds hold for the scalar Padé tail (hence,
        // by the bit-parity test above, for the vector kernels too).
        let mut rng = Rng::new(23);
        let (rows, hid) = (4usize, 64usize);
        let gates: Vec<f32> = (0..rows * 4 * hid).map(|_| rng.uniform(-10.0, 10.0)).collect();
        // c stays in the bound's |c| ≤ 2 regime.
        let c0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let (mut hp, mut cp) = (vec![0.0; rows * hid], c0.clone());
        let (mut hl, mut cl) = (vec![0.0; rows * hid], c0.clone());
        lstm_tail_pade_scalar(&gates, &mut hp, &mut cp, rows, hid);
        lstm_tail_scalar(&gates, &mut hl, &mut cl, rows, hid);
        for k in 0..rows * hid {
            let dc = (cp[k] - cl[k]).abs();
            let dh = (hp[k] - hl[k]).abs();
            assert!(dc <= TAIL_C_MAX_ABS_ERR, "c[{k}]: {dc}");
            assert!(dh <= TAIL_H_MAX_ABS_ERR, "h[{k}]: {dh}");
        }
    }
}
