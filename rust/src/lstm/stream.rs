//! Incremental (streaming) LSTM execution — per-step inference that
//! resumes from persisted h/c state (DESIGN.md §11).
//!
//! One-shot classification throws the recurrent state away after every
//! `[T, I]` window. Streaming workloads (continuous speech, keyword
//! spotting) instead feed an unbounded frame sequence and want logits
//! after every step. [`StreamState`] holds exactly the state a window
//! pass would have accumulated — one h and one c plane per layer — and
//! [`LstmModel::stream_chunk`] advances it frame by frame through the
//! *same* public kernels the batched plan uses ([`step_rows`] /
//! [`step_rows_quant`] at `rows = 1`), with the head accumulated in the
//! same order as `forward_rows`.
//!
//! That shared-kernel discipline is the parity contract: T single-step
//! calls from a fresh state produce h/c and logits **bit-for-bit equal**
//! to one `forward_batch` over the concatenated `[T, I]` window (f32),
//! and `stream_chunk_quant` likewise matches `forward_batch_quant`
//! bit-for-bit — verified in `rust/tests/sessions.rs`. Note what the
//! contract does *not* depend on: chunking. Streaming 1+1+…+1 frames,
//! one T-frame chunk, or any split in between all visit the identical
//! per-element accumulation sequence. The dispatched fused gate tail
//! (DESIGN.md §14) preserves this: within one process/ISA config the
//! tail kernel is per-element with a fixed op chain, so batched, pooled
//! and streaming execution share one accuracy contract for BOTH
//! precisions — asserted across `PlanPool` thread counts in
//! `rust/tests/tail.rs`.
//!
//! h/c stay f32 even for int8 sessions: the quantized path (DESIGN.md
//! §10) quantizes weights and per-step activations but carries state in
//! f32 precisely so requantization error cannot compound across
//! timesteps — for a long-lived stream that property is load-bearing,
//! not an implementation detail.

use crate::config::ModelShape;
use crate::lstm::model::LstmModel;
use crate::lstm::plan::step_rows;
use crate::lstm::quant::{step_rows_quant, QuantScratch, QuantizedLstmModel};

/// Persistent per-stream recurrent state: one `[H]` h plane and one
/// `[H]` c plane per layer, plus the scratch buffers a single-row step
/// needs (`[4H]` gates; lazily-grown quant scratch). Steady-state
/// streaming performs zero heap allocations.
#[derive(Debug, Clone)]
pub struct StreamState {
    shape: ModelShape,
    h: Vec<Vec<f32>>,
    c: Vec<Vec<f32>>,
    gates: Vec<f32>,
    quant: QuantScratch,
    steps: u64,
}

impl StreamState {
    pub fn new(shape: ModelShape) -> Self {
        Self {
            shape,
            h: vec![vec![0.0; shape.hidden]; shape.num_layers],
            c: vec![vec![0.0; shape.hidden]; shape.num_layers],
            gates: vec![0.0; 4 * shape.hidden],
            quant: QuantScratch::default(),
            steps: 0,
        }
    }

    pub fn shape(&self) -> ModelShape {
        self.shape
    }

    /// Total frames consumed since the state was opened (or last reset).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The h plane of `layer` — exposed for parity tests and state
    /// inspection; `[H]` floats.
    pub fn h_plane(&self, layer: usize) -> &[f32] {
        &self.h[layer]
    }

    /// The c plane of `layer`; `[H]` floats.
    pub fn c_plane(&self, layer: usize) -> &[f32] {
        &self.c[layer]
    }

    /// Zero all planes and the step counter, as if freshly opened.
    pub fn reset(&mut self) {
        for v in self.h.iter_mut().chain(self.c.iter_mut()) {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        self.steps = 0;
    }
}

impl LstmModel {
    /// Advance `state` through `steps` frames (`frames` is flat
    /// `[steps, I]`, row-major) and return flat `[steps, C]` logits —
    /// one logits row *per step*, computed from the last layer's h after
    /// that step.
    ///
    /// Drives [`step_rows`] at `rows = 1` from the stored planes, so a
    /// fresh state streamed through a full window reproduces
    /// `forward_batch` bit-for-bit (see module docs).
    pub fn stream_chunk(&self, frames: &[f32], steps: usize, state: &mut StreamState) -> Vec<f32> {
        let s = self.shape;
        assert_eq!(state.shape(), s, "stream state built for a different model shape");
        assert!(steps >= 1, "stream_chunk needs at least one frame");
        assert_eq!(frames.len(), steps * s.input_dim);
        let layers = self.cell_layers();
        let mut logits = vec![0.0f32; steps * s.num_classes];
        for t in 0..steps {
            let x = &frames[t * s.input_dim..(t + 1) * s.input_dim];
            for li in 0..s.num_layers {
                // Same split-borrow trick as the batched plan: layer li
                // reads layer li-1's fresh h while mutating its own.
                let (prev, cur) = state.h.split_at_mut(li);
                let input: &[f32] = if li == 0 { x } else { &prev[li - 1] };
                step_rows(&layers[li], input, &mut cur[0], &mut state.c[li], &mut state.gates, 1);
            }
            self.head_into(
                &state.h[s.num_layers - 1],
                &mut logits[t * s.num_classes..(t + 1) * s.num_classes],
            );
        }
        state.steps += steps as u64;
        logits
    }

    /// Single-frame convenience wrapper over [`Self::stream_chunk`].
    pub fn stream_step(&self, frame: &[f32], state: &mut StreamState) -> Vec<f32> {
        self.stream_chunk(frame, 1, state)
    }
}

impl QuantizedLstmModel {
    /// Int8 mirror of [`LstmModel::stream_chunk`]: advances the *same*
    /// f32 h/c planes through [`step_rows_quant`] at `rows = 1`. State
    /// stays f32 (see module docs); a fresh state streamed through a
    /// full window reproduces `forward_batch_quant` bit-for-bit.
    pub fn stream_chunk_quant(
        &self,
        frames: &[f32],
        steps: usize,
        state: &mut StreamState,
    ) -> Vec<f32> {
        let s = self.shape;
        assert_eq!(state.shape(), s, "stream state built for a different model shape");
        assert!(steps >= 1, "stream_chunk_quant needs at least one frame");
        assert_eq!(frames.len(), steps * s.input_dim);
        let layers = self.layers();
        let k_max = layers.iter().map(|l| l.k_padded_max()).max().unwrap_or(0);
        state.quant.reserve(1, k_max, 4 * s.hidden);
        let mut logits = vec![0.0f32; steps * s.num_classes];
        for t in 0..steps {
            let x = &frames[t * s.input_dim..(t + 1) * s.input_dim];
            for li in 0..s.num_layers {
                let (prev, cur) = state.h.split_at_mut(li);
                let input: &[f32] = if li == 0 { x } else { &prev[li - 1] };
                step_rows_quant(
                    &layers[li],
                    input,
                    &mut cur[0],
                    &mut state.c[li],
                    &mut state.gates,
                    &mut state.quant,
                    1,
                );
            }
            self.head_into(
                &state.h[s.num_layers - 1],
                &mut logits[t * s.num_classes..(t + 1) * s.num_classes],
            );
        }
        state.steps += steps as u64;
        logits
    }
}
