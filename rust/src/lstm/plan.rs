//! Batched time-major execution plan for the native LSTM stack
//! (DESIGN.md §8).
//!
//! The per-window path (`model::forward_window`) runs one GEMV per
//! timestep per layer, re-reading every layer's `[I+H, 4H]` weight
//! matrix B times per batch. This module restructures the same math into
//! coarser work units — MobiRNN §3.3's work-unit factorization applied
//! to the batch dimension: at each `(t, layer)` step the WHOLE batch
//! advances through one blocked GEMM (`tensor::matmul_into`), so each
//! quad of weight rows is loaded once and feeds four batch rows.
//!
//! Two pieces:
//!
//! - [`BatchArena`] — the preallocated state of one in-flight batch:
//!   `[B, H]` h/c planes per layer, one `[B, 4H]` gate buffer shared by
//!   all layers, and a `[B, I]` staging plane for the current timestep's
//!   layer-0 input. Planes grow monotonically and are reused across
//!   batches, extending the paper's §3.2 "preallocate and reuse c/h"
//!   discipline from one window to a whole batch.
//! - [`step_rows`] — the batched cell kernel: one LSTM step for `rows`
//!   batch rows at once, numerically bit-for-bit with `rows` calls to
//!   [`lstm_cell`](crate::lstm::cell::lstm_cell) (same per-element
//!   accumulation order; asserted by `rust/tests/batched_plan.rs`).
//!
//! Loop order is TIME-MAJOR, layer inner (`for t { for layer }`), the
//! same order as the per-window path: each step's GEMM input is the
//! previous layer's freshly-written `[rows, H]` h-plane, so layers chain
//! in place with zero copies; only layer 0 needs a gather from the
//! `[B, T, D]` input into the `[rows, I]` staging plane.

use crate::config::ModelShape;
use crate::lstm::cell::{sigmoid, LstmCellWeights, FORGET_BIAS};
use crate::lstm::quant::{step_rows_quant, QuantScratch, QuantizedCellWeights};
use crate::tensor::matmul_into;

/// Preallocated per-batch state: every buffer the time-major plan writes.
///
/// Owned by whoever drives batches — `CpuSingleEngine` holds one behind
/// its mutex, every `ThreadedLstm` worker owns one, benches hold one per
/// thread of measurement. Never shared across concurrent batches.
#[derive(Debug, Clone)]
pub struct BatchArena {
    shape: ModelShape,
    /// Rows the planes currently hold; grows monotonically, never shrinks.
    capacity: usize,
    /// Per layer: a row-major `[capacity, H]` plane.
    h: Vec<Vec<f32>>,
    c: Vec<Vec<f32>>,
    /// `[capacity, 4H]` gate buffer, shared by all layers within a step.
    gates: Vec<f32>,
    /// `[capacity, I]` staging plane for the current timestep's gathered
    /// layer-0 input (`x[:, t, :]` is strided in the `[B, T, D]` window
    /// data; the GEMM wants it contiguous).
    xt: Vec<f32>,
    /// Int8-path scratch (DESIGN.md §10): empty until the first
    /// [`BatchArena::run_quant`], so pure-f32 serving pays nothing.
    quant: QuantScratch,
}

impl BatchArena {
    /// An arena sized for one row; grows on first bigger batch.
    pub fn new(shape: ModelShape) -> Self {
        Self::with_capacity(shape, 1)
    }

    /// An arena pre-sized for `rows` batch rows.
    pub fn with_capacity(shape: ModelShape, rows: usize) -> Self {
        let mut arena = Self {
            shape,
            capacity: 0,
            h: vec![Vec::new(); shape.num_layers],
            c: vec![Vec::new(); shape.num_layers],
            gates: Vec::new(),
            xt: Vec::new(),
            quant: QuantScratch::default(),
        };
        arena.reserve_rows(rows.max(1));
        arena
    }

    pub fn shape(&self) -> ModelShape {
        self.shape
    }

    /// Batch rows the planes can hold without reallocating.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Grow every plane to hold `rows` batch rows (no-op when they fit).
    /// The only allocation site in the batched hot path — steady-state
    /// serving at a stable max batch never allocates.
    pub fn reserve_rows(&mut self, rows: usize) {
        if rows <= self.capacity {
            return;
        }
        let s = self.shape;
        for plane in self.h.iter_mut().chain(self.c.iter_mut()) {
            plane.resize(rows * s.hidden, 0.0);
        }
        self.gates.resize(rows * 4 * s.hidden, 0.0);
        self.xt.resize(rows * s.input_dim, 0.0);
        self.capacity = rows;
    }

    /// Zero the first `rows` rows of every h/c plane (fresh batch).
    fn reset(&mut self, rows: usize) {
        self.reserve_rows(rows);
        let n = rows * self.shape.hidden;
        for plane in self.h.iter_mut().chain(self.c.iter_mut()) {
            plane[..n].fill(0.0);
        }
    }

    /// Advance `rows` windows (`windows` is flat `[rows, T, D]` data)
    /// time-major through the stacked layers. Returns the last layer's
    /// `[rows, H]` h-plane for the caller's head computation.
    ///
    /// Allocation-free once the arena has grown to `rows`.
    pub fn run(&mut self, layers: &[LstmCellWeights], windows: &[f32], rows: usize) -> &[f32] {
        self.run_impl(Layers::F32(layers), windows, rows)
    }

    /// [`BatchArena::run`]'s int8 mirror (DESIGN.md §10): the SAME
    /// time-major driver, with the per-`(t, layer)` step swapped for
    /// [`step_rows_quant`]'s quantize → integer GEMM → requantize →
    /// fast-tail sequence. The h/c planes stay f32 (the recurrence input
    /// of the next step), so error does not compound across timesteps.
    ///
    /// Allocation-free once the arena (and its lazily-grown quant
    /// scratch) has seen `rows`.
    pub fn run_quant(
        &mut self,
        layers: &[QuantizedCellWeights],
        windows: &[f32],
        rows: usize,
    ) -> &[f32] {
        self.run_impl(Layers::Quant(layers), windows, rows)
    }

    /// The one time-major driver behind both precisions: gather
    /// `x[:, t, :]` into the contiguous staging plane, then chain the
    /// layers in place — each layer's input is layer 0's staging plane
    /// or the previous layer's freshly-written h-plane (split-borrow,
    /// zero copies).
    fn run_impl(&mut self, layers: Layers<'_>, windows: &[f32], rows: usize) -> &[f32] {
        let s = self.shape;
        let n_layers = match layers {
            Layers::F32(l) => l.len(),
            Layers::Quant(l) => l.len(),
        };
        assert_eq!(n_layers, s.num_layers, "layer count");
        assert_eq!(windows.len(), rows * s.seq_len * s.input_dim, "window data");
        self.reset(rows);
        if let Layers::Quant(l) = layers {
            let kp_max = l.iter().map(QuantizedCellWeights::k_padded_max).max().unwrap_or(4);
            self.quant.reserve(rows, kp_max, 4 * s.hidden);
        }
        let window_len = s.seq_len * s.input_dim;
        let hn = rows * s.hidden;
        for t in 0..s.seq_len {
            // Gather x[:, t, :] into the contiguous [rows, I] staging plane.
            for (b, dst) in self.xt[..rows * s.input_dim].chunks_exact_mut(s.input_dim).enumerate()
            {
                let at = b * window_len + t * s.input_dim;
                dst.copy_from_slice(&windows[at..at + s.input_dim]);
            }
            for li in 0..s.num_layers {
                // split_at_mut(0) leaves `prev` empty and `cur[0]` the
                // first h-plane, so layer 0 needs no special borrow.
                let (prev, cur) = self.h.split_at_mut(li);
                let input: &[f32] = if li == 0 {
                    &self.xt[..rows * s.input_dim]
                } else {
                    &prev[li - 1][..hn]
                };
                match layers {
                    Layers::F32(l) => step_rows(
                        &l[li],
                        input,
                        &mut cur[0][..hn],
                        &mut self.c[li][..hn],
                        &mut self.gates,
                        rows,
                    ),
                    Layers::Quant(l) => step_rows_quant(
                        &l[li],
                        input,
                        &mut cur[0][..hn],
                        &mut self.c[li][..hn],
                        &mut self.gates,
                        &mut self.quant,
                        rows,
                    ),
                }
            }
        }
        &self.h[s.num_layers - 1][..hn]
    }
}

/// The two precision tiers [`BatchArena::run_impl`] can drive — same
/// loop, different per-step kernel.
#[derive(Clone, Copy)]
enum Layers<'a> {
    F32(&'a [LstmCellWeights]),
    Quant(&'a [QuantizedCellWeights]),
}

/// One LSTM step for `rows` batch rows at once, in place: reads `xs`
/// (`[rows, I]`), overwrites `h`/`c` (`[rows, H]`) with the next state.
/// `gates` must hold at least `rows * 4H` values.
///
/// The gate pre-activations for ALL rows come from two blocked GEMMs
/// over the combined weight matrix — the per-row GEMV pair of
/// [`lstm_cell`](crate::lstm::cell::lstm_cell) widened so each loaded
/// quad of weight rows feeds four batch rows. The point-wise tail stays
/// fused per row. Bit-for-bit equal to `rows` independent `lstm_cell`
/// calls.
pub fn step_rows(
    weights: &LstmCellWeights,
    xs: &[f32],
    h: &mut [f32],
    c: &mut [f32],
    gates: &mut [f32],
    rows: usize,
) {
    let hid = weights.hidden;
    let in_dim = weights.input_dim;
    debug_assert_eq!(xs.len(), rows * in_dim);
    debug_assert_eq!(h.len(), rows * hid);
    debug_assert_eq!(c.len(), rows * hid);
    debug_assert!(gates.len() >= rows * 4 * hid);
    let gates = &mut gates[..rows * 4 * hid];
    let w = weights.w.data();
    let b = weights.b.data();

    // gates[r] = b (broadcast init), then one pass over each W half.
    for grow in gates.chunks_exact_mut(4 * hid) {
        grow.copy_from_slice(b);
    }
    matmul_into(gates, xs, w, rows, in_dim, 4 * hid);
    matmul_into(gates, h, &w[in_dim * 4 * hid..], rows, hid, 4 * hid);

    // Fused point-wise tail (i, g, f, o) per row, writing h/c in place.
    for ((grow, hrow), crow) in gates
        .chunks_exact(4 * hid)
        .zip(h.chunks_exact_mut(hid))
        .zip(c.chunks_exact_mut(hid))
    {
        let (ig, rest) = grow.split_at(hid);
        let (gg, rest) = rest.split_at(hid);
        let (fg, og) = rest.split_at(hid);
        for k in 0..hid {
            let c_next = sigmoid(fg[k] + FORGET_BIAS) * crow[k] + sigmoid(ig[k]) * gg[k].tanh();
            crow[k] = c_next;
            hrow[k] = sigmoid(og[k]) * c_next.tanh();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_cell_weights as rand_weights;
    use crate::lstm::cell::{lstm_cell, CellScratch};
    use crate::util::Rng;

    #[test]
    fn step_rows_bitwise_matches_per_row_cell() {
        let mut rng = Rng::new(51);
        for &(rows, in_dim, hid) in
            &[(1usize, 9usize, 32usize), (3, 9, 32), (4, 5, 8), (7, 3, 17), (8, 32, 32)]
        {
            let w = rand_weights(&mut rng, in_dim, hid);
            let xs: Vec<f32> = (0..rows * in_dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let h0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();

            let mut h = h0.clone();
            let mut c = c0.clone();
            let mut gates = vec![0.0f32; rows * 4 * hid];
            step_rows(&w, &xs, &mut h, &mut c, &mut gates, rows);

            let mut scratch = CellScratch::new(hid);
            for r in 0..rows {
                let mut hr = h0[r * hid..(r + 1) * hid].to_vec();
                let mut cr = c0[r * hid..(r + 1) * hid].to_vec();
                lstm_cell(&w, &xs[r * in_dim..(r + 1) * in_dim], &mut hr, &mut cr, &mut scratch);
                assert_eq!(&h[r * hid..(r + 1) * hid], &hr[..], "h row {r} ({rows},{in_dim},{hid})");
                assert_eq!(&c[r * hid..(r + 1) * hid], &cr[..], "c row {r} ({rows},{in_dim},{hid})");
            }
        }
    }

    #[test]
    fn arena_grows_monotonically_and_is_reusable() {
        let shape = ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 4, num_classes: 4 };
        let mut rng = Rng::new(52);
        let layers: Vec<LstmCellWeights> = {
            let mut v = Vec::new();
            let mut in_dim = shape.input_dim;
            for _ in 0..shape.num_layers {
                v.push(rand_weights(&mut rng, in_dim, shape.hidden));
                in_dim = shape.hidden;
            }
            v
        };
        let mut arena = BatchArena::new(shape);
        assert_eq!(arena.capacity(), 1);
        let windows: Vec<f32> =
            (0..5 * shape.seq_len * shape.input_dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let first = arena.run(&layers, &windows, 5).to_vec();
        assert_eq!(arena.capacity(), 5);
        // Re-running the same batch through the reused arena must give
        // identical results (full h/c reset, no state leak).
        let second = arena.run(&layers, &windows, 5).to_vec();
        assert_eq!(first, second);
        // A smaller batch must not shrink capacity.
        let _ = arena.run(&layers, &windows[..2 * shape.seq_len * shape.input_dim], 2);
        assert_eq!(arena.capacity(), 5);
    }

    #[test]
    #[should_panic]
    fn run_rejects_wrong_window_len() {
        let shape = ModelShape { num_layers: 1, hidden: 4, input_dim: 2, seq_len: 3, num_classes: 2 };
        let mut rng = Rng::new(53);
        let layers = vec![rand_weights(&mut rng, 2, 4)];
        let mut arena = BatchArena::new(shape);
        let _ = arena.run(&layers, &[0.0; 5], 1);
    }
}
