//! Batched time-major execution plan for the native LSTM stack
//! (DESIGN.md §8, intra-batch parallelism §13).
//!
//! The per-window path (`model::forward_window`) runs one GEMV per
//! timestep per layer, re-reading every layer's `[I+H, 4H]` weight
//! matrix B times per batch. This module restructures the same math into
//! coarser work units — MobiRNN §3.3's work-unit factorization applied
//! to the batch dimension: at each `(t, layer)` step the WHOLE batch
//! advances through one blocked GEMM (`tensor::matmul_into`), so each
//! quad of weight rows is loaded once and feeds four batch rows.
//!
//! Three pieces:
//!
//! - [`BatchArena`] — the preallocated state of one in-flight batch:
//!   `[B, H]` h/c planes per layer, one `[B, 4H]` gate buffer shared by
//!   all layers, and a `[B, I]` staging plane for the current timestep's
//!   layer-0 input. Planes grow monotonically and are reused across
//!   batches, extending the paper's §3.2 "preallocate and reuse c/h"
//!   discipline from one window to a whole batch.
//! - [`step_rows`] — the batched cell kernel: one LSTM step for `rows`
//!   batch rows at once, numerically bit-for-bit with `rows` calls to
//!   [`lstm_cell`](crate::lstm::cell::lstm_cell) (same per-element
//!   accumulation order; asserted by `rust/tests/batched_plan.rs`).
//! - [`PlanPool`] — a persistent intra-batch worker pool. With a pool
//!   attached ([`BatchArena::set_pool`]), one batch's rows are split
//!   into contiguous ranges ([`chunk_spans`] — the same chunking
//!   discipline `lstm::threaded` uses across batches) and each range
//!   runs the FULL time-major loop on its own worker over disjoint
//!   sub-planes of the shared arena. Rows of a batch never interact —
//!   the h/c recurrence is sequential in *t*, not across rows — so the
//!   partitioned run is bit-for-bit equal to the inline run (each row's
//!   per-element accumulation chain is unchanged; asserted below). This
//!   is what lets `CpuSingleEngine`/`CpuQuantEngine` scale with cores
//!   instead of batch count.
//!
//! Loop order is TIME-MAJOR, layer inner (`for t { for layer }`), the
//! same order as the per-window path: each step's GEMM input is the
//! previous layer's freshly-written `[rows, H]` h-plane, so layers chain
//! in place with zero copies; only layer 0 needs a gather from the
//! `[B, T, D]` input into the `[rows, I]` staging plane.

use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use crate::config::ModelShape;
use crate::lstm::cell::LstmCellWeights;
use crate::lstm::quant::{step_rows_quant_slices, QuantScratch, QuantizedCellWeights};
use crate::tensor::matmul_into;

/// Contiguous `(start, rows)` spans covering `total` rows in chunks of
/// at most `chunk` rows — the chunking discipline shared by the
/// cross-batch dispatcher (`lstm::threaded`) and the intra-batch
/// partitioner here. `chunk` must be ≥ 1; the final span absorbs the
/// remainder.
pub fn chunk_spans(total: usize, chunk: usize) -> Vec<(usize, usize)> {
    assert!(chunk >= 1, "chunk must be >= 1");
    let mut spans = Vec::with_capacity(total.div_ceil(chunk.max(1)));
    let mut start = 0;
    while start < total {
        let rows = chunk.min(total - start);
        spans.push((start, rows));
        start += rows;
    }
    spans
}

/// A job queued on the intra-batch pool. Tasks are erased to `'static`
/// by [`PlanPool::run_scoped`], which guarantees they complete before
/// the borrowed data they capture goes away.
enum PoolJob {
    Run(Box<dyn FnOnce() + Send + 'static>),
    Shutdown,
}

/// A persistent worker pool for splitting ONE batch's work across
/// cores. `new(t)` spawns `t - 1` OS threads (the caller's thread is
/// always the t-th worker, so `new(1)` spawns nothing and
/// [`PlanPool::run_scoped`] degrades to plain inline execution).
///
/// Workers share one queue behind a mutexed receiver (the
/// `lstm::threaded` worker pattern) and live until the pool drops, so
/// steady-state serving pays no thread spawns per batch — the pool is
/// built once per engine and shared via `Arc` across that engine's
/// arenas.
pub struct PlanPool {
    tx: Mutex<mpsc::Sender<PoolJob>>,
    threads: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

impl PlanPool {
    /// A pool that runs scoped task sets on `threads` threads total
    /// (caller + `threads - 1` spawned workers).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (1..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mobirnn-plan-{i}"))
                    .spawn(move || loop {
                        // Take the job while holding the lock, run it after
                        // releasing so workers pull in parallel.
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(PoolJob::Run(task)) => {
                                // A panicking task must not kill the worker:
                                // queued siblings would never drain and the
                                // scoped caller could never observe completion.
                                // The dropped-without-send done channel turns
                                // the panic into a caller-side panic instead.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(task),
                                );
                            }
                            Ok(PoolJob::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn plan pool worker")
            })
            .collect();
        Self { tx: Mutex::new(tx), threads, workers }
    }

    /// A pool sized to the host's available parallelism.
    pub fn with_default_threads() -> Self {
        Self::new(thread::available_parallelism().map_or(1, |n| n.get()))
    }

    /// Total execution lanes (spawned workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a set of tasks that may borrow the caller's stack, blocking
    /// until every one of them has completed. The last task runs on the
    /// calling thread (it would otherwise idle-wait); the rest go to the
    /// workers. If the pool has no workers, everything runs inline.
    ///
    /// Panics if a queued task panicked on a worker — by then all other
    /// tasks have finished, so the borrowed data is quiescent either way.
    pub fn run_scoped<'scope>(&self, mut tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if self.workers.is_empty() || tasks.len() <= 1 {
            for task in tasks {
                task();
            }
            return;
        }
        let inline = tasks.pop().expect("tasks.len() > 1");
        let queued = tasks.len();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        {
            let tx = self.tx.lock().unwrap();
            for task in tasks {
                let done = done_tx.clone();
                let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    task();
                    let _ = done.send(());
                });
                // SAFETY: only the lifetime is transmuted ('scope ->
                // 'static); Box<dyn FnOnce> layout does not depend on it.
                // This function does not return until `queued` completions
                // (or a closed channel, which only happens after every
                // other queued task finished or was dropped unrun) have
                // been observed, so no task outlives 'scope.
                let wrapped = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(wrapped)
                };
                tx.send(PoolJob::Run(wrapped)).expect("plan pool workers alive");
            }
        }
        drop(done_tx);
        inline();
        for _ in 0..queued {
            if done_rx.recv().is_err() {
                // Every sender is gone but not every completion arrived:
                // some task was dropped without finishing (it panicked on
                // its worker). All other tasks have drained by now.
                panic!("plan pool task panicked");
            }
        }
    }
}

impl fmt::Debug for PlanPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PlanPool").field("threads", &self.threads).finish()
    }
}

impl Drop for PlanPool {
    fn drop(&mut self) {
        {
            let tx = self.tx.lock().unwrap_or_else(|p| p.into_inner());
            for _ in &self.workers {
                let _ = tx.send(PoolJob::Shutdown);
            }
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Preallocated per-batch state: every buffer the time-major plan writes.
///
/// Owned by whoever drives batches — `CpuSingleEngine` holds one behind
/// its mutex, every `ThreadedLstm` worker owns one, benches hold one per
/// thread of measurement. Never shared across concurrent batches.
#[derive(Debug, Clone)]
pub struct BatchArena {
    shape: ModelShape,
    /// Rows the planes currently hold; grows monotonically, never shrinks.
    capacity: usize,
    /// Per layer: a row-major `[capacity, H]` plane.
    h: Vec<Vec<f32>>,
    c: Vec<Vec<f32>>,
    /// `[capacity, 4H]` gate buffer, shared by all layers within a step.
    gates: Vec<f32>,
    /// `[capacity, I]` staging plane for the current timestep's gathered
    /// layer-0 input (`x[:, t, :]` is strided in the `[B, T, D]` window
    /// data; the GEMM wants it contiguous).
    xt: Vec<f32>,
    /// Int8-path scratch (DESIGN.md §10): empty until the first
    /// [`BatchArena::run_quant`], so pure-f32 serving pays nothing.
    quant: QuantScratch,
    /// Intra-batch worker pool (DESIGN.md §13): `None` runs every batch
    /// inline on the calling thread, exactly as before.
    pool: Option<Arc<PlanPool>>,
}

impl BatchArena {
    /// An arena sized for one row; grows on first bigger batch.
    pub fn new(shape: ModelShape) -> Self {
        Self::with_capacity(shape, 1)
    }

    /// An arena pre-sized for `rows` batch rows.
    pub fn with_capacity(shape: ModelShape, rows: usize) -> Self {
        let mut arena = Self {
            shape,
            capacity: 0,
            h: vec![Vec::new(); shape.num_layers],
            c: vec![Vec::new(); shape.num_layers],
            gates: Vec::new(),
            xt: Vec::new(),
            quant: QuantScratch::default(),
            pool: None,
        };
        arena.reserve_rows(rows.max(1));
        arena
    }

    /// An arena with an intra-batch pool attached from the start.
    pub fn with_pool(shape: ModelShape, pool: Arc<PlanPool>) -> Self {
        let mut arena = Self::new(shape);
        arena.set_pool(pool);
        arena
    }

    /// Attach a persistent intra-batch worker pool: every subsequent
    /// `run`/`run_quant` splits its batch's rows across
    /// `pool.threads()` lanes (bit-for-bit equal to the inline run).
    /// Several arenas may share one pool — its queue serializes task
    /// sets, which is exactly right when the arenas belong to the same
    /// engine.
    pub fn set_pool(&mut self, pool: Arc<PlanPool>) {
        self.pool = Some(pool);
    }

    pub fn shape(&self) -> ModelShape {
        self.shape
    }

    /// Batch rows the planes can hold without reallocating.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Grow every plane to hold `rows` batch rows (no-op when they fit).
    /// The only allocation site in the batched hot path — steady-state
    /// serving at a stable max batch never allocates.
    pub fn reserve_rows(&mut self, rows: usize) {
        if rows <= self.capacity {
            return;
        }
        let s = self.shape;
        for plane in self.h.iter_mut().chain(self.c.iter_mut()) {
            plane.resize(rows * s.hidden, 0.0);
        }
        self.gates.resize(rows * 4 * s.hidden, 0.0);
        self.xt.resize(rows * s.input_dim, 0.0);
        self.capacity = rows;
    }

    /// Zero the first `rows` rows of every h/c plane (fresh batch).
    fn reset(&mut self, rows: usize) {
        self.reserve_rows(rows);
        let n = rows * self.shape.hidden;
        for plane in self.h.iter_mut().chain(self.c.iter_mut()) {
            plane[..n].fill(0.0);
        }
    }

    /// Advance `rows` windows (`windows` is flat `[rows, T, D]` data)
    /// time-major through the stacked layers. Returns the last layer's
    /// `[rows, H]` h-plane for the caller's head computation.
    ///
    /// Allocation-free once the arena has grown to `rows` (modulo the
    /// per-range task boxes when an intra-batch pool is attached).
    pub fn run(&mut self, layers: &[LstmCellWeights], windows: &[f32], rows: usize) -> &[f32] {
        self.run_impl(Layers::F32(layers), windows, rows)
    }

    /// [`BatchArena::run`]'s int8 mirror (DESIGN.md §10): the SAME
    /// time-major driver, with the per-`(t, layer)` step swapped for
    /// [`step_rows_quant`](crate::lstm::quant::step_rows_quant)'s
    /// quantize → integer GEMM → requantize →
    /// fast-tail sequence. The h/c planes stay f32 (the recurrence input
    /// of the next step), so error does not compound across timesteps.
    ///
    /// Allocation-free once the arena (and its lazily-grown quant
    /// scratch) has seen `rows`.
    pub fn run_quant(
        &mut self,
        layers: &[QuantizedCellWeights],
        windows: &[f32],
        rows: usize,
    ) -> &[f32] {
        self.run_impl(Layers::Quant(layers), windows, rows)
    }

    /// The one time-major driver behind both precisions. Without a pool
    /// (or for single-row batches) the whole batch runs inline as one
    /// row range; with a pool, rows split into contiguous ranges — each
    /// range owns disjoint sub-planes of h/c/gates/xt (and the quant
    /// scratch) and runs the full `for t { for layer }` loop
    /// independently, because the recurrence couples timesteps, never
    /// batch rows.
    fn run_impl(&mut self, layers: Layers<'_>, windows: &[f32], rows: usize) -> &[f32] {
        let s = self.shape;
        let n_layers = match layers {
            Layers::F32(l) => l.len(),
            Layers::Quant(l) => l.len(),
        };
        assert_eq!(n_layers, s.num_layers, "layer count");
        assert_eq!(windows.len(), rows * s.seq_len * s.input_dim, "window data");
        self.reset(rows);
        let mut kp_max = 0;
        if let Layers::Quant(l) = layers {
            kp_max = l.iter().map(QuantizedCellWeights::k_padded_max).max().unwrap_or(4);
            self.quant.reserve(rows, kp_max, 4 * s.hidden);
        }
        let parts = match &self.pool {
            Some(pool) if rows >= 2 => pool.threads().min(rows),
            _ => 1,
        };
        let spans = chunk_spans(rows, rows.div_ceil(parts.max(1)).max(1));
        let pool = self.pool.clone();
        {
            let quant = matches!(layers, Layers::Quant(_)).then_some(&mut self.quant);
            let mut ranges =
                split_ranges(&mut self.h, &mut self.c, &mut self.gates, &mut self.xt, quant, s,
                    kp_max, &spans);
            if ranges.len() <= 1 {
                if let Some(range) = ranges.pop() {
                    run_range(layers, s, windows, range);
                }
            } else {
                let pool = pool.expect("multiple ranges only form with a pool attached");
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                    .into_iter()
                    .map(|range| {
                        Box::new(move || run_range(layers, s, windows, range))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_scoped(tasks);
            }
        }
        &self.h[s.num_layers - 1][..rows * s.hidden]
    }
}

/// The two precision tiers [`BatchArena::run_impl`] can drive — same
/// loop, different per-step kernel.
#[derive(Clone, Copy)]
enum Layers<'a> {
    F32(&'a [LstmCellWeights]),
    Quant(&'a [QuantizedCellWeights]),
}

/// One contiguous row range's mutable view of every arena plane — what
/// a single intra-batch worker owns for the duration of a batch.
struct RowRange<'a> {
    /// First batch row of this range (offset into `windows`).
    start: usize,
    rows: usize,
    /// Per layer: this range's `[rows, H]` h/c sub-planes.
    h: Vec<&'a mut [f32]>,
    c: Vec<&'a mut [f32]>,
    gates: &'a mut [f32],
    xt: &'a mut [f32],
    quant: Option<QuantViews<'a>>,
}

/// This range's rows of the quant scratch planes.
struct QuantViews<'a> {
    qa: &'a mut [i8],
    qacc: &'a mut [i32],
    qscale: &'a mut [f32],
}

/// Split every arena plane into per-span disjoint sub-slices. All
/// planes are row-major with contiguous rows, so each span is one
/// `split_at_mut` per plane.
#[allow(clippy::too_many_arguments)]
fn split_ranges<'a>(
    h: &'a mut [Vec<f32>],
    c: &'a mut [Vec<f32>],
    gates: &'a mut [f32],
    xt: &'a mut [f32],
    quant: Option<&'a mut QuantScratch>,
    s: ModelShape,
    kp_max: usize,
    spans: &[(usize, usize)],
) -> Vec<RowRange<'a>> {
    let total: usize = spans.iter().map(|&(_, rows)| rows).sum();
    let mut ranges: Vec<RowRange<'a>> = spans
        .iter()
        .map(|&(start, rows)| RowRange {
            start,
            rows,
            h: Vec::with_capacity(s.num_layers),
            c: Vec::with_capacity(s.num_layers),
            gates: &mut [],
            xt: &mut [],
            quant: None,
        })
        .collect();
    for (planes, field) in [(h, 0usize), (c, 1)] {
        for plane in planes.iter_mut() {
            let mut rest = &mut plane[..total * s.hidden];
            for range in ranges.iter_mut() {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(range.rows * s.hidden);
                if field == 0 {
                    range.h.push(head);
                } else {
                    range.c.push(head);
                }
                rest = tail;
            }
        }
    }
    let mut rest = &mut gates[..total * 4 * s.hidden];
    for range in ranges.iter_mut() {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(range.rows * 4 * s.hidden);
        range.gates = head;
        rest = tail;
    }
    let mut rest = &mut xt[..total * s.input_dim];
    for range in ranges.iter_mut() {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(range.rows * s.input_dim);
        range.xt = head;
        rest = tail;
    }
    if let Some(q) = quant {
        let mut qa = &mut q.qa[..total * kp_max];
        let mut qacc = &mut q.qacc[..total * 4 * s.hidden];
        let mut qscale = &mut q.qscale[..total];
        for range in ranges.iter_mut() {
            let (qa_head, qa_tail) = std::mem::take(&mut qa).split_at_mut(range.rows * kp_max);
            qa = qa_tail;
            let (qacc_head, qacc_tail) =
                std::mem::take(&mut qacc).split_at_mut(range.rows * 4 * s.hidden);
            qacc = qacc_tail;
            let (qs_head, qs_tail) = std::mem::take(&mut qscale).split_at_mut(range.rows);
            qscale = qs_tail;
            range.quant = Some(QuantViews { qa: qa_head, qacc: qacc_head, qscale: qs_head });
        }
    }
    ranges
}

/// Run the full time-major loop over one row range. Ranges are fully
/// independent: the LSTM recurrence chains h/c across TIMESTEPS within
/// a row, never across rows, so each range can sweep all of `t` on its
/// own thread while reading the shared `windows`.
fn run_range(layers: Layers<'_>, s: ModelShape, windows: &[f32], mut range: RowRange<'_>) {
    let rows = range.rows;
    let window_len = s.seq_len * s.input_dim;
    let hn = rows * s.hidden;
    for t in 0..s.seq_len {
        // Gather this range's x[:, t, :] into its contiguous staging rows.
        for (b, dst) in range.xt[..rows * s.input_dim].chunks_exact_mut(s.input_dim).enumerate() {
            let at = (range.start + b) * window_len + t * s.input_dim;
            dst.copy_from_slice(&windows[at..at + s.input_dim]);
        }
        for li in 0..s.num_layers {
            // split_at_mut(li) leaves `prev` the layers below and
            // `cur[0]` this layer's h-plane, so layer 0 needs no special
            // borrow.
            let (prev, cur) = range.h.split_at_mut(li);
            let input: &[f32] = if li == 0 {
                &range.xt[..rows * s.input_dim]
            } else {
                &prev[li - 1][..hn]
            };
            match layers {
                Layers::F32(l) => step_rows(
                    &l[li],
                    input,
                    &mut cur[0][..hn],
                    &mut range.c[li][..hn],
                    range.gates,
                    rows,
                ),
                Layers::Quant(l) => {
                    let q = range.quant.as_mut().expect("quant scratch views");
                    step_rows_quant_slices(
                        &l[li],
                        input,
                        &mut cur[0][..hn],
                        &mut range.c[li][..hn],
                        range.gates,
                        q.qa,
                        q.qacc,
                        q.qscale,
                        rows,
                    )
                }
            }
        }
    }
}

/// One LSTM step for `rows` batch rows at once, in place: reads `xs`
/// (`[rows, I]`), overwrites `h`/`c` (`[rows, H]`) with the next state.
/// `gates` must hold at least `rows * 4H` values.
///
/// The gate pre-activations for ALL rows come from two blocked GEMMs
/// over the combined weight matrix — the per-row GEMV pair of
/// [`lstm_cell`](crate::lstm::cell::lstm_cell) widened so each loaded
/// quad of weight rows feeds four batch rows. The point-wise tail stays
/// fused per row. Bit-for-bit equal to `rows` independent `lstm_cell`
/// calls.
pub fn step_rows(
    weights: &LstmCellWeights,
    xs: &[f32],
    h: &mut [f32],
    c: &mut [f32],
    gates: &mut [f32],
    rows: usize,
) {
    let hid = weights.hidden;
    let in_dim = weights.input_dim;
    debug_assert_eq!(xs.len(), rows * in_dim);
    debug_assert_eq!(h.len(), rows * hid);
    debug_assert_eq!(c.len(), rows * hid);
    debug_assert!(gates.len() >= rows * 4 * hid);
    let gates = &mut gates[..rows * 4 * hid];
    let w = weights.w.data();
    let b = weights.b.data();

    // gates[r] = b (broadcast init), then one pass over each W half.
    for grow in gates.chunks_exact_mut(4 * hid) {
        grow.copy_from_slice(b);
    }
    matmul_into(gates, xs, w, rows, in_dim, 4 * hid);
    matmul_into(gates, h, &w[in_dim * 4 * hid..], rows, hid, 4 * hid);

    // Fused point-wise tail (i, g, f, o) per row, writing h/c in place —
    // the dispatched kernel (DESIGN.md §14). Per-element with a fixed
    // per-row op chain, so PlanPool row partitions stay bit-for-bit
    // equal to the inline run under every ISA.
    crate::lstm::tail::lstm_tail(gates, h, c, rows, hid);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_cell_weights as rand_weights;
    use crate::lstm::cell::{lstm_cell, CellScratch};
    use crate::util::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_spans_cover_exactly_once() {
        assert_eq!(chunk_spans(0, 3), vec![]);
        assert_eq!(chunk_spans(1, 3), vec![(0, 1)]);
        assert_eq!(chunk_spans(6, 2), vec![(0, 2), (2, 2), (4, 2)]);
        assert_eq!(chunk_spans(7, 3), vec![(0, 3), (3, 3), (6, 1)]);
        for total in 0..20usize {
            for chunk in 1..8usize {
                let spans = chunk_spans(total, chunk);
                let mut next = 0;
                for &(start, rows) in &spans {
                    assert_eq!(start, next, "contiguous");
                    assert!(rows >= 1 && rows <= chunk);
                    next += rows;
                }
                assert_eq!(next, total, "total={total} chunk={chunk}");
            }
        }
    }

    #[test]
    fn plan_pool_runs_every_task_and_is_reusable() {
        let pool = PlanPool::new(3);
        assert_eq!(pool.threads(), 3);
        for round in 1..=3usize {
            let counter = AtomicUsize::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
            assert_eq!(counter.load(Ordering::SeqCst), 8, "round {round}");
        }
    }

    #[test]
    fn plan_pool_single_thread_runs_inline() {
        let pool = PlanPool::new(1);
        assert_eq!(pool.threads(), 1);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn step_rows_bitwise_matches_per_row_cell() {
        let mut rng = Rng::new(51);
        for &(rows, in_dim, hid) in
            &[(1usize, 9usize, 32usize), (3, 9, 32), (4, 5, 8), (7, 3, 17), (8, 32, 32)]
        {
            let w = rand_weights(&mut rng, in_dim, hid);
            let xs: Vec<f32> = (0..rows * in_dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let h0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();

            let mut h = h0.clone();
            let mut c = c0.clone();
            let mut gates = vec![0.0f32; rows * 4 * hid];
            step_rows(&w, &xs, &mut h, &mut c, &mut gates, rows);

            let mut scratch = CellScratch::new(hid);
            for r in 0..rows {
                let mut hr = h0[r * hid..(r + 1) * hid].to_vec();
                let mut cr = c0[r * hid..(r + 1) * hid].to_vec();
                lstm_cell(&w, &xs[r * in_dim..(r + 1) * in_dim], &mut hr, &mut cr, &mut scratch);
                assert_eq!(&h[r * hid..(r + 1) * hid], &hr[..], "h row {r} ({rows},{in_dim},{hid})");
                assert_eq!(&c[r * hid..(r + 1) * hid], &cr[..], "c row {r} ({rows},{in_dim},{hid})");
            }
        }
    }

    #[test]
    fn arena_grows_monotonically_and_is_reusable() {
        let shape = ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 4, num_classes: 4 };
        let mut rng = Rng::new(52);
        let layers: Vec<LstmCellWeights> = {
            let mut v = Vec::new();
            let mut in_dim = shape.input_dim;
            for _ in 0..shape.num_layers {
                v.push(rand_weights(&mut rng, in_dim, shape.hidden));
                in_dim = shape.hidden;
            }
            v
        };
        let mut arena = BatchArena::new(shape);
        assert_eq!(arena.capacity(), 1);
        let windows: Vec<f32> =
            (0..5 * shape.seq_len * shape.input_dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let first = arena.run(&layers, &windows, 5).to_vec();
        assert_eq!(arena.capacity(), 5);
        // Re-running the same batch through the reused arena must give
        // identical results (full h/c reset, no state leak).
        let second = arena.run(&layers, &windows, 5).to_vec();
        assert_eq!(first, second);
        // A smaller batch must not shrink capacity.
        let _ = arena.run(&layers, &windows[..2 * shape.seq_len * shape.input_dim], 2);
        assert_eq!(arena.capacity(), 5);
    }

    #[test]
    fn partitioned_run_is_bitwise_equal_to_inline() {
        // Rows never interact within a batch, and every kernel's
        // per-element accumulation chain is independent of the M split,
        // so the pool-partitioned run must reproduce the inline run bit
        // for bit — f32 and int8, across chunk remainders.
        let shape =
            ModelShape { num_layers: 2, hidden: 16, input_dim: 5, seq_len: 6, num_classes: 4 };
        let mut rng = Rng::new(54);
        let mut layers = Vec::new();
        let mut qlayers = Vec::new();
        let mut in_dim = shape.input_dim;
        for _ in 0..shape.num_layers {
            let w = rand_weights(&mut rng, in_dim, shape.hidden);
            qlayers.push(QuantizedCellWeights::quantize(&w));
            layers.push(w);
            in_dim = shape.hidden;
        }
        let pool = Arc::new(PlanPool::new(3));
        let mut inline = BatchArena::new(shape);
        let mut pooled = BatchArena::with_pool(shape, Arc::clone(&pool));
        for rows in [1usize, 2, 5, 7, 8] {
            let windows: Vec<f32> = (0..rows * shape.seq_len * shape.input_dim)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let f_inline = inline.run(&layers, &windows, rows).to_vec();
            let f_pooled = pooled.run(&layers, &windows, rows).to_vec();
            assert_eq!(f_inline, f_pooled, "f32 rows={rows}");
            let q_inline = inline.run_quant(&qlayers, &windows, rows).to_vec();
            let q_pooled = pooled.run_quant(&qlayers, &windows, rows).to_vec();
            assert_eq!(q_inline, q_pooled, "quant rows={rows}");
        }
    }

    #[test]
    #[should_panic]
    fn run_rejects_wrong_window_len() {
        let shape = ModelShape { num_layers: 1, hidden: 4, input_dim: 2, seq_len: 3, num_classes: 2 };
        let mut rng = Rng::new(53);
        let layers = vec![rand_weights(&mut rng, 2, 4)];
        let mut arena = BatchArena::new(shape);
        let _ = arena.run(&layers, &[0.0; 5], 1);
    }
}
