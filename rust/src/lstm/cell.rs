//! One LSTM cell step — numerics mirror `python/compile/kernels/ref.py`.
//!
//! Gate layout: `gates = [x;h] @ W + b`, split (i, g, f, o);
//! `c' = σ(f + 1) ⊙ c + σ(i) ⊙ tanh(g)`, `h' = σ(o) ⊙ tanh(c')`.
//!
//! The hot loop applies the paper's §3.3 CPU-side optimizations:
//! - combined input+hidden GEMM (one pass over W, not two);
//! - fused point-wise tail (gates never leave the scratch buffer);
//! - caller-provided scratch so the serving loop never allocates
//!   (§3.2's "preallocate and reuse c/h" on the CPU path).

use crate::tensor::{gemv_into, Tensor};

/// TensorFlow BasicLSTMCell forget-gate bias, as trained (ref.py).
pub const FORGET_BIAS: f32 = 1.0;

/// Weights of one layer: combined `[I+H, 4H]` matrix + `[4H]` bias.
#[derive(Debug, Clone)]
pub struct LstmCellWeights {
    pub w: Tensor,
    pub b: Tensor,
    pub input_dim: usize,
    pub hidden: usize,
}

impl LstmCellWeights {
    pub fn new(w: Tensor, b: Tensor, input_dim: usize, hidden: usize) -> Self {
        assert_eq!(w.shape(), &[input_dim + hidden, 4 * hidden], "W shape");
        assert_eq!(b.shape(), &[4 * hidden], "b shape");
        Self { w, b, input_dim, hidden }
    }
}

#[inline(always)]
pub(crate) fn sigmoid(x: f32) -> f32 {
    // Numerically-stable logistic, matching ref.py's select form.
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Per-call scratch: the `[4H]` gate buffer. Reused across timesteps by
/// the model loop so the inner path is allocation-free.
#[derive(Debug, Clone)]
pub struct CellScratch {
    pub gates: Vec<f32>,
}

impl CellScratch {
    pub fn new(hidden: usize) -> Self {
        Self { gates: vec![0.0; 4 * hidden] }
    }
}

/// One cell step for ONE batch row, in place:
/// reads `x` (len I) and `h`/`c` (len H), overwrites `h`/`c` with the
/// next state. `scratch.gates` must be sized `4H`.
pub fn lstm_cell(
    weights: &LstmCellWeights,
    x: &[f32],
    h: &mut [f32],
    c: &mut [f32],
    scratch: &mut CellScratch,
) {
    let hid = weights.hidden;
    let in_dim = weights.input_dim;
    debug_assert_eq!(x.len(), in_dim);
    debug_assert_eq!(h.len(), hid);
    debug_assert_eq!(c.len(), hid);
    let gates = &mut scratch.gates[..4 * hid];
    let w = weights.w.data();
    let b = weights.b.data();

    // gates = b  (init), then accumulate rows of W scaled by [x;h].
    gates.copy_from_slice(b);
    // Row-major W: row r holds the 4H outputs for input feature r, so the
    // GEMV walks W exactly once, row by row — this is the "combined
    // inputs and weights" single pass (paper §3.3). `gemv_into` processes
    // rows FOUR at a time so the `gates` accumulator is read/written once
    // per quad instead of once per row (≈4× less accumulator traffic; see
    // EXPERIMENTS.md §Perf — ~2.3× on the full window forward). The
    // batched plan (`lstm::plan`) runs the same math through
    // `tensor::matmul_into` with the identical per-element order.
    gemv_into(gates, w, x);
    gemv_into(gates, &w[in_dim * 4 * hid..], h);

    // Fused point-wise tail (i, g, f, o), writing h/c in place — through
    // the dispatch table (DESIGN.md §14), same kernel as the batched,
    // pooled and streaming paths at rows = 1.
    crate::lstm::tail::lstm_tail(gates, h, c, 1, hid);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::random_cell_weights as rand_weights;
    use crate::util::Rng;

    /// Unoptimized oracle: explicit concat + naive matmul, textbook gates.
    fn cell_oracle(w: &LstmCellWeights, x: &[f32], h: &[f32], c: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let hid = w.hidden;
        let mut xh = x.to_vec();
        xh.extend_from_slice(h);
        let mut gates = w.b.data().to_vec();
        for (j, g) in gates.iter_mut().enumerate() {
            for (r, &v) in xh.iter().enumerate() {
                *g += v * w.w.data()[r * 4 * hid + j];
            }
        }
        let mut hn = vec![0.0; hid];
        let mut cn = c.to_vec();
        // Same dispatched tail as lstm_cell: this oracle checks the GEMM
        // half (naive concat matmul vs quad-blocked GEMV), so the tail
        // must be common-moded out — its own parity is covered by
        // lstm::tail's tests and rust/tests/tail.rs.
        crate::lstm::tail::lstm_tail(&gates, &mut hn, &mut cn, 1, hid);
        (hn, cn)
    }

    #[test]
    fn matches_oracle() {
        let mut rng = Rng::new(1);
        for &(i, h) in &[(9usize, 32usize), (32, 32), (9, 64), (3, 5)] {
            let w = rand_weights(&mut rng, i, h);
            let x: Vec<f32> = (0..i).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut hv: Vec<f32> = (0..h).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut cv: Vec<f32> = (0..h).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let (h_exp, c_exp) = cell_oracle(&w, &x, &hv, &cv);
            let mut scratch = CellScratch::new(h);
            lstm_cell(&w, &x, &mut hv, &mut cv, &mut scratch);
            for k in 0..h {
                assert!((hv[k] - h_exp[k]).abs() < 1e-5, "h[{k}]");
                assert!((cv[k] - c_exp[k]).abs() < 1e-5, "c[{k}]");
            }
        }
    }

    #[test]
    fn zero_input_keeps_bounded_state() {
        let mut rng = Rng::new(2);
        let w = rand_weights(&mut rng, 9, 16);
        let mut h = vec![0.0; 16];
        let mut c = vec![0.0; 16];
        let mut s = CellScratch::new(16);
        for _ in 0..100 {
            lstm_cell(&w, &[0.0; 9], &mut h, &mut c, &mut s);
        }
        // |h| <= 1 always (sigmoid * tanh); c stays finite via forget < 1.
        assert!(h.iter().all(|v| v.abs() <= 1.0 && v.is_finite()));
        assert!(c.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forget_gate_saturation_preserves_cell() {
        // With huge forget bias contribution and zero input gate the cell
        // state must persist ~unchanged (the LSTM memory mechanism, §2.1).
        let hid = 4;
        let in_dim = 2;
        let mut w = vec![0.0; (in_dim + hid) * 4 * hid];
        // force f-gate pre-activation very positive, i-gate very negative
        let b: Vec<f32> = (0..4 * hid)
            .map(|j| {
                if (hid..2 * hid).contains(&j) {
                    0.0
                } else if (2 * hid..3 * hid).contains(&j) {
                    20.0 // forget
                } else if j < hid {
                    -20.0 // input
                } else {
                    0.0 // output
                }
            })
            .collect();
        w.iter_mut().for_each(|v| *v = 0.0);
        let weights = LstmCellWeights::new(
            Tensor::new(vec![in_dim + hid, 4 * hid], w),
            Tensor::new(vec![4 * hid], b),
            in_dim,
            hid,
        );
        let mut h = vec![0.0; hid];
        let mut c = vec![0.7; hid];
        let mut s = CellScratch::new(hid);
        for _ in 0..50 {
            lstm_cell(&weights, &[1.0, -1.0], &mut h, &mut c, &mut s);
        }
        // The Padé tail's σ saturates at 0.99962 rather than 1.0, so over
        // 50 steps the cell decays by up to 0.99962^50 ≈ 0.981× on SIMD
        // hosts; the libm tail holds it to f32 rounding.
        let tol = if crate::kernel::active() == crate::kernel::KernelIsa::Scalar {
            1e-4
        } else {
            0.02
        };
        for &cv in &c {
            assert!((cv - 0.7).abs() < tol, "cell state leaked: {cv}");
        }
    }

    #[test]
    fn sigmoid_stable_extremes() {
        assert_eq!(sigmoid(1000.0), 1.0);
        assert_eq!(sigmoid(-1000.0), 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        // symmetric: σ(-x) = 1 - σ(x)
        for x in [-5.0f32, -1.0, 0.3, 2.5] {
            assert!((sigmoid(-x) - (1.0 - sigmoid(x))).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic]
    fn weights_shape_checked() {
        LstmCellWeights::new(Tensor::zeros(vec![10, 10]), Tensor::zeros(vec![8]), 9, 2);
    }
}
