//! Int8 quantized execution path for the native LSTM stack
//! (DESIGN.md §10).
//!
//! The f32 batched plan (DESIGN.md §8) spends its time in two places:
//! the blocked GEMMs over each layer's `[I+H, 4H]` weight matrix, and
//! the `exp`/`tanh` point-wise tail. This module attacks both:
//!
//! - **Weights** are quantized once at load time — symmetric, per
//!   OUTPUT channel (one scale per gate column `j` per GEMM half,
//!   `s_j = max_r |W[r][j]| / 127`) — into [`PackedQuantMatrix`]es: a
//!   row-major int8 image whose K dimension is padded to a multiple of
//!   4 AT PACK TIME, so [`quant_matmul_into`] runs pure quad-K blocks
//!   with no remainder path (the padding rows are zero and contribute
//!   nothing).
//! - **Activations** are quantized per batch row per step (dynamic
//!   symmetric), multiplied in `i8×i8→i32`, and REQUANTIZED back to f32
//!   while being written into the existing gate buffer:
//!   `gates[m][j] = b[j] + acc_x · s_x[m] · s_xj + acc_h · s_h[m] · s_hj`.
//!   The step runs TWO integer GEMMs — input half, then recurrent half
//!   — exactly like the f32 cell's two `matmul_into` calls, and for a
//!   precision reason too: `x` (raw sensor data, range ~±2.5) and `h`
//!   (bounded by 1) get SEPARATE dynamic scales, so the wide input
//!   range cannot crush the recurrent state's resolution. Everything
//!   downstream of the GEMMs — the gate tail, h/c state, the classifier
//!   head — stays f32: the LSTM recurrence feeds h back into the next
//!   step's GEMM input, and keeping state in f32 stops quantization
//!   error from compounding across the 128 timesteps (DESIGN.md §10 has
//!   the error budget).
//! - **The tail** goes through the dispatched fused gate kernel
//!   ([`crate::lstm::tail::lstm_tail`], DESIGN.md §14) — the same entry
//!   the f32 batched/pooled/streaming paths use. On SIMD hosts that is
//!   the vector Padé (5,4) kernel, bit-identical to the scalar
//!   [`fast_sigmoid`]/[`fast_tanh`] loop this module ran historically
//!   (the approximation originated here; its bounds
//!   [`TANH_MAX_ABS_ERR`]/[`SIGMOID_MAX_ABS_ERR`] are dense-sweep
//!   asserted by `rust/tests/quant.rs`). Under the forced-scalar ISA the
//!   int8 tier now gets the exact libm tail instead — slightly MORE
//!   accurate, and it means end-to-end int8 bit-exactness across ISA
//!   configs holds at the GEMM level, not the full forward (DESIGN.md
//!   §14 records this contract change).
//!
//! Since the SIMD work (DESIGN.md §13), [`quant_matmul_into`] routes
//! through the process-wide [`crate::kernel::dispatch`] table: a
//! widening i8×i8→i16→i32 AVX2 kernel on capable x86_64, `vmlal_s16`
//! NEON on aarch64, and the original scalar kernel
//! ([`quant_matmul_into_scalar`]) everywhere else. Integer addition is
//! associative and every product fits comfortably (`127² · K ≪ 2³¹`),
//! so ALL implementations are bit-exact with each other — asserted by
//! `rust/tests/simd_parity.rs`.
//!
//! The scalar kernel mirrors `tensor::matmul_into_scalar`'s blocking
//! exactly — quad-M output rows over quad-K weight rows, duo/single M
//! tails — so the weight-reuse argument (one loaded quad of `W` rows
//! feeds four batch rows) carries over unchanged; the int8 image is 4×
//! denser, so the same traversal moves a quarter of the bytes.
//!
//! Accuracy gate: this path is NOT bit-exact with f32 and never claims
//! to be. Its contract is argmax parity — ≥ 99% agreement with the f32
//! oracle on HAR-shaped inputs — plus the per-channel half-step bound
//! on the weight round-trip, both asserted in `rust/tests/quant.rs`.

use crate::config::ModelShape;
use crate::lstm::cell::LstmCellWeights;
use crate::lstm::plan::BatchArena;
use crate::tensor::{argmax_slice, Tensor};

// The Padé helpers were born in this module (PR 4) and moved to
// `lstm::tail` when the tail became a dispatched kernel; re-exported
// here so `lstm::quant::{fast_tanh, ...}` call sites keep compiling.
pub use crate::lstm::tail::{fast_sigmoid, fast_tanh, SIGMOID_MAX_ABS_ERR, TANH_MAX_ABS_ERR};

/// Round `k` up to the next multiple of 4 (the kernel's K quad).
#[inline]
pub fn pad_to_quad(k: usize) -> usize {
    (k + 3) & !3
}

/// A weight matrix quantized symmetrically per output channel and
/// pre-packed for [`quant_matmul_into`]: row-major `[k_padded, n]` int8
/// with `k_padded = pad_to_quad(k)`; the padding rows are zero, so the
/// kernel needs no K remainder path.
#[derive(Debug, Clone)]
pub struct PackedQuantMatrix {
    data: Vec<i8>,
    /// Logical row count of the source matrix.
    pub k: usize,
    /// Stored row count (quad-padded; the tail rows are all-zero).
    pub k_padded: usize,
    /// Output channels (columns).
    pub n: usize,
    /// Per-output-channel dequantization scale: `w[r][j] ≈ q[r][j]·s[j]`.
    pub scales: Vec<f32>,
}

impl PackedQuantMatrix {
    /// Quantize a row-major `[k, n]` f32 matrix. Symmetric per-channel:
    /// `s_j = max_r |w[r][j]| / 127`, `q = round(w / s_j)`; an all-zero
    /// channel gets scale 0 (its products dequantize to exactly 0).
    pub fn pack(w: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(w.len(), k * n, "matrix shape");
        let mut scales = vec![0.0f32; n];
        for row in w.chunks_exact(n) {
            for (s, &v) in scales.iter_mut().zip(row) {
                *s = s.max(v.abs());
            }
        }
        for s in &mut scales {
            *s /= 127.0;
        }
        let k_padded = pad_to_quad(k);
        let mut data = vec![0i8; k_padded * n];
        for (qrow, row) in data.chunks_exact_mut(n).zip(w.chunks_exact(n)) {
            for ((q, &v), &s) in qrow.iter_mut().zip(row).zip(&scales) {
                if s > 0.0 {
                    *q = (v / s).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
        Self { data, k, k_padded, n, scales }
    }

    /// Dequantize back to a row-major `[k, n]` f32 matrix (padding rows
    /// dropped) — the round-trip side of the half-step error bound.
    pub fn unpack(&self) -> Vec<f32> {
        self.data[..self.k * self.n]
            .chunks_exact(self.n)
            .flat_map(|qrow| qrow.iter().zip(&self.scales).map(|(&q, &s)| q as f32 * s))
            .collect()
    }

    /// The packed int8 image, row-major `[k_padded, n]`.
    pub fn data(&self) -> &[i8] {
        &self.data
    }
}

/// `acc[m][j] += Σ_r a[m][r] · w[r][j]` in `i8×i8→i32` — the integer
/// mirror of `tensor::matmul_into`, via the process-wide kernel table
/// ([`crate::kernel::dispatch`]). `a` is row-major `[m, w.k_padded]`
/// with the padding lanes zero. Bit-exact across every implementation
/// (integer accumulation is associative).
pub fn quant_matmul_into(acc: &mut [i32], a: &[i8], w: &PackedQuantMatrix, m: usize) {
    debug_assert_eq!(acc.len(), m * w.n, "acc shape");
    debug_assert_eq!(a.len(), m * w.k_padded, "a shape");
    (crate::kernel::dispatch().quant_matmul)(acc, a, &w.data, m, w.k_padded, w.n)
}

/// [`quant_matmul_into`] pinned to the scalar kernel — the parity
/// oracle for `rust/tests/simd_parity.rs` regardless of what the
/// dispatch table selected.
pub fn quant_matmul_into_scalar(acc: &mut [i32], a: &[i8], w: &PackedQuantMatrix, m: usize) {
    debug_assert_eq!(acc.len(), m * w.n, "acc shape");
    debug_assert_eq!(a.len(), m * w.k_padded, "a shape");
    quant_matmul_scalar(acc, a, &w.data, m, w.k_padded, w.n)
}

/// The scalar integer GEMM over the raw packed image (row-major
/// `[kp, n]` with `kp % 4 == 0`): output rows blocked in quads (each
/// loaded quad of packed weight rows feeds four accumulator rows), K
/// blocked in quads with NO remainder (packing padded K), a duo-M block
/// for 2–3 row tails, single rows last.
pub fn quant_matmul_scalar(acc: &mut [i32], a: &[i8], wd: &[i8], m: usize, kp: usize, n: usize) {
    debug_assert_eq!(kp % 4, 0, "packed K must be quad-padded");
    debug_assert_eq!(acc.len(), m * n, "acc shape");
    debug_assert_eq!(a.len(), m * kp, "a shape");
    debug_assert!(wd.len() >= kp * n, "W too small");
    // i8·i8 ≤ 127² = 16129 per term: kp below ~133k rows cannot overflow
    // the i32 accumulator even if every product saturates.
    debug_assert!(kp < (i32::MAX as usize) / (127 * 127), "K too large for i32 acc");
    let mut mi = 0;
    while mi + 4 <= m {
        let (o01, o23) = acc[mi * n..(mi + 4) * n].split_at_mut(2 * n);
        let (o0, o1) = o01.split_at_mut(n);
        let (o2, o3) = o23.split_at_mut(n);
        let a0 = &a[mi * kp..(mi + 1) * kp];
        let a1 = &a[(mi + 1) * kp..(mi + 2) * kp];
        let a2 = &a[(mi + 2) * kp..(mi + 3) * kp];
        let a3 = &a[(mi + 3) * kp..(mi + 4) * kp];
        let mut r = 0;
        while r < kp {
            let base = r * n;
            let w0 = &wd[base..base + n];
            let w1 = &wd[base + n..base + 2 * n];
            let w2 = &wd[base + 2 * n..base + 3 * n];
            let w3 = &wd[base + 3 * n..base + 4 * n];
            let (a00, a01v, a02, a03) =
                (a0[r] as i32, a0[r + 1] as i32, a0[r + 2] as i32, a0[r + 3] as i32);
            let (a10, a11, a12, a13) =
                (a1[r] as i32, a1[r + 1] as i32, a1[r + 2] as i32, a1[r + 3] as i32);
            let (a20, a21, a22, a23) =
                (a2[r] as i32, a2[r + 1] as i32, a2[r + 2] as i32, a2[r + 3] as i32);
            let (a30, a31, a32, a33) =
                (a3[r] as i32, a3[r + 1] as i32, a3[r + 2] as i32, a3[r + 3] as i32);
            for j in 0..n {
                let (x0, x1, x2, x3) = (w0[j] as i32, w1[j] as i32, w2[j] as i32, w3[j] as i32);
                o0[j] += a00 * x0 + a01v * x1 + a02 * x2 + a03 * x3;
                o1[j] += a10 * x0 + a11 * x1 + a12 * x2 + a13 * x3;
                o2[j] += a20 * x0 + a21 * x1 + a22 * x2 + a23 * x3;
                o3[j] += a30 * x0 + a31 * x1 + a32 * x2 + a33 * x3;
            }
            r += 4;
        }
        mi += 4;
    }
    if mi + 2 <= m {
        let (o0, o1) = acc[mi * n..(mi + 2) * n].split_at_mut(n);
        let a0 = &a[mi * kp..(mi + 1) * kp];
        let a1 = &a[(mi + 1) * kp..(mi + 2) * kp];
        let mut r = 0;
        while r < kp {
            let base = r * n;
            let w0 = &wd[base..base + n];
            let w1 = &wd[base + n..base + 2 * n];
            let w2 = &wd[base + 2 * n..base + 3 * n];
            let w3 = &wd[base + 3 * n..base + 4 * n];
            let (a00, a01v, a02, a03) =
                (a0[r] as i32, a0[r + 1] as i32, a0[r + 2] as i32, a0[r + 3] as i32);
            let (a10, a11, a12, a13) =
                (a1[r] as i32, a1[r + 1] as i32, a1[r + 2] as i32, a1[r + 3] as i32);
            for j in 0..n {
                let (x0, x1, x2, x3) = (w0[j] as i32, w1[j] as i32, w2[j] as i32, w3[j] as i32);
                o0[j] += a00 * x0 + a01v * x1 + a02 * x2 + a03 * x3;
                o1[j] += a10 * x0 + a11 * x1 + a12 * x2 + a13 * x3;
            }
            r += 4;
        }
        mi += 2;
    }
    while mi < m {
        let orow = &mut acc[mi * n..(mi + 1) * n];
        let arow = &a[mi * kp..(mi + 1) * kp];
        let mut r = 0;
        while r < kp {
            let base = r * n;
            let w0 = &wd[base..base + n];
            let w1 = &wd[base + n..base + 2 * n];
            let w2 = &wd[base + 2 * n..base + 3 * n];
            let w3 = &wd[base + 3 * n..base + 4 * n];
            let (a00, a01v, a02, a03) =
                (arow[r] as i32, arow[r + 1] as i32, arow[r + 2] as i32, arow[r + 3] as i32);
            for j in 0..n {
                orow[j] += a00 * w0[j] as i32
                    + a01v * w1[j] as i32
                    + a02 * w2[j] as i32
                    + a03 * w3[j] as i32;
            }
            r += 4;
        }
        mi += 1;
    }
}

/// AVX2 int8 GEMM: widening i8×i8→i16→i32 dot products, 16 output
/// channels per vector step. Weights widen via `_mm256_cvtepi8_epi16`,
/// products run in `_mm256_mullo_epi16` (exact: |i8·i8| ≤ 127² < 2¹⁵),
/// then widen to i32 and accumulate. Bit-exact with the scalar kernel —
/// integer adds in any order. M-blocks of 4 rows reuse each widened
/// weight vector; remaining rows run singly (no duo block needed, the
/// result is identical by associativity).
#[cfg(target_arch = "x86_64")]
pub(crate) mod simd {
    use std::arch::x86_64::*;

    pub(crate) fn quant_matmul_avx2(
        acc: &mut [i32],
        a: &[i8],
        wd: &[i8],
        m: usize,
        kp: usize,
        n: usize,
    ) {
        debug_assert_eq!(kp % 4, 0, "packed K must be quad-padded");
        debug_assert_eq!(acc.len(), m * n, "acc shape");
        debug_assert_eq!(a.len(), m * kp, "a shape");
        debug_assert!(wd.len() >= kp * n, "W too small");
        debug_assert!(kp < (i32::MAX as usize) / (127 * 127), "K too large for i32 acc");
        // SAFETY: the dispatch table installs this entry only after
        // `is_x86_feature_detected!("avx2")` held; the shape asserts
        // bound every pointer offset used inside.
        unsafe { qmm_avx2(acc.as_mut_ptr(), a.as_ptr(), wd.as_ptr(), m, kp, n) }
    }

    /// # Safety
    /// Requires AVX2; `acc`/`a`/`wd` must be valid for `m*n` / `m*kp` /
    /// `kp*n` element accesses.
    #[target_feature(enable = "avx2")]
    unsafe fn qmm_avx2(acc: *mut i32, a: *const i8, wd: *const i8, m: usize, kp: usize, n: usize) {
        unsafe {
            let mut mi = 0;
            while mi + 4 <= m {
                qrows4_avx2(acc.add(mi * n), a.add(mi * kp), wd, kp, n);
                mi += 4;
            }
            while mi < m {
                qrow1_avx2(acc.add(mi * n), a.add(mi * kp), wd, kp, n);
                mi += 1;
            }
        }
    }

    /// # Safety
    /// Requires AVX2; 4 accumulator rows at `o`, 4 activation rows at `a`.
    #[target_feature(enable = "avx2")]
    unsafe fn qrows4_avx2(o: *mut i32, a: *const i8, wd: *const i8, kp: usize, n: usize) {
        unsafe {
            let (o0, o1, o2, o3) = (o, o.add(n), o.add(2 * n), o.add(3 * n));
            let (a0, a1, a2, a3) = (a, a.add(kp), a.add(2 * kp), a.add(3 * kp));
            let mut j = 0;
            while j + 16 <= n {
                let mut s0l = _mm256_loadu_si256(o0.add(j) as *const __m256i);
                let mut s0h = _mm256_loadu_si256(o0.add(j + 8) as *const __m256i);
                let mut s1l = _mm256_loadu_si256(o1.add(j) as *const __m256i);
                let mut s1h = _mm256_loadu_si256(o1.add(j + 8) as *const __m256i);
                let mut s2l = _mm256_loadu_si256(o2.add(j) as *const __m256i);
                let mut s2h = _mm256_loadu_si256(o2.add(j + 8) as *const __m256i);
                let mut s3l = _mm256_loadu_si256(o3.add(j) as *const __m256i);
                let mut s3h = _mm256_loadu_si256(o3.add(j + 8) as *const __m256i);
                for r in 0..kp {
                    // 16 packed weights → i16 lanes, shared by 4 rows.
                    let w16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        wd.add(r * n + j) as *const __m128i
                    ));
                    let p0 = _mm256_mullo_epi16(_mm256_set1_epi16(*a0.add(r) as i16), w16);
                    let p1 = _mm256_mullo_epi16(_mm256_set1_epi16(*a1.add(r) as i16), w16);
                    let p2 = _mm256_mullo_epi16(_mm256_set1_epi16(*a2.add(r) as i16), w16);
                    let p3 = _mm256_mullo_epi16(_mm256_set1_epi16(*a3.add(r) as i16), w16);
                    s0l = _mm256_add_epi32(s0l, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p0)));
                    s0h = _mm256_add_epi32(
                        s0h,
                        _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p0)),
                    );
                    s1l = _mm256_add_epi32(s1l, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p1)));
                    s1h = _mm256_add_epi32(
                        s1h,
                        _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p1)),
                    );
                    s2l = _mm256_add_epi32(s2l, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p2)));
                    s2h = _mm256_add_epi32(
                        s2h,
                        _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p2)),
                    );
                    s3l = _mm256_add_epi32(s3l, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p3)));
                    s3h = _mm256_add_epi32(
                        s3h,
                        _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p3)),
                    );
                }
                _mm256_storeu_si256(o0.add(j) as *mut __m256i, s0l);
                _mm256_storeu_si256(o0.add(j + 8) as *mut __m256i, s0h);
                _mm256_storeu_si256(o1.add(j) as *mut __m256i, s1l);
                _mm256_storeu_si256(o1.add(j + 8) as *mut __m256i, s1h);
                _mm256_storeu_si256(o2.add(j) as *mut __m256i, s2l);
                _mm256_storeu_si256(o2.add(j + 8) as *mut __m256i, s2h);
                _mm256_storeu_si256(o3.add(j) as *mut __m256i, s3l);
                _mm256_storeu_si256(o3.add(j + 8) as *mut __m256i, s3h);
                j += 16;
            }
            while j < n {
                let (mut s0, mut s1, mut s2, mut s3) =
                    (*o0.add(j), *o1.add(j), *o2.add(j), *o3.add(j));
                for r in 0..kp {
                    let wv = *wd.add(r * n + j) as i32;
                    s0 += *a0.add(r) as i32 * wv;
                    s1 += *a1.add(r) as i32 * wv;
                    s2 += *a2.add(r) as i32 * wv;
                    s3 += *a3.add(r) as i32 * wv;
                }
                *o0.add(j) = s0;
                *o1.add(j) = s1;
                *o2.add(j) = s2;
                *o3.add(j) = s3;
                j += 1;
            }
        }
    }

    /// # Safety
    /// Requires AVX2; 1 accumulator row at `o`, 1 activation row at `a`.
    #[target_feature(enable = "avx2")]
    unsafe fn qrow1_avx2(o: *mut i32, a: *const i8, wd: *const i8, kp: usize, n: usize) {
        unsafe {
            let mut j = 0;
            while j + 16 <= n {
                let mut sl = _mm256_loadu_si256(o.add(j) as *const __m256i);
                let mut sh = _mm256_loadu_si256(o.add(j + 8) as *const __m256i);
                for r in 0..kp {
                    let w16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        wd.add(r * n + j) as *const __m128i
                    ));
                    let p = _mm256_mullo_epi16(_mm256_set1_epi16(*a.add(r) as i16), w16);
                    sl = _mm256_add_epi32(sl, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p)));
                    sh = _mm256_add_epi32(
                        sh,
                        _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p)),
                    );
                }
                _mm256_storeu_si256(o.add(j) as *mut __m256i, sl);
                _mm256_storeu_si256(o.add(j + 8) as *mut __m256i, sh);
                j += 16;
            }
            while j < n {
                let mut s = *o.add(j);
                for r in 0..kp {
                    s += *a.add(r) as i32 * *wd.add(r * n + j) as i32;
                }
                *o.add(j) = s;
                j += 1;
            }
        }
    }
}

/// NEON int8 GEMM: widening i8→i16 (`vmovl_s8`) then `vmlal_n_s16`
/// multiply-accumulate into i32x4 halves, 8 output channels per vector
/// step. Bit-exact with the scalar kernel (integer adds in any order).
#[cfg(target_arch = "aarch64")]
pub(crate) mod simd {
    use std::arch::aarch64::*;

    pub(crate) fn quant_matmul_neon(
        acc: &mut [i32],
        a: &[i8],
        wd: &[i8],
        m: usize,
        kp: usize,
        n: usize,
    ) {
        debug_assert_eq!(kp % 4, 0, "packed K must be quad-padded");
        debug_assert_eq!(acc.len(), m * n, "acc shape");
        debug_assert_eq!(a.len(), m * kp, "a shape");
        debug_assert!(wd.len() >= kp * n, "W too small");
        debug_assert!(kp < (i32::MAX as usize) / (127 * 127), "K too large for i32 acc");
        // SAFETY: NEON is architecturally guaranteed on aarch64; the
        // shape asserts bound every pointer offset used inside.
        unsafe { qmm_neon(acc.as_mut_ptr(), a.as_ptr(), wd.as_ptr(), m, kp, n) }
    }

    /// # Safety
    /// `acc`/`a`/`wd` must be valid for `m*n` / `m*kp` / `kp*n` element
    /// accesses.
    #[target_feature(enable = "neon")]
    unsafe fn qmm_neon(acc: *mut i32, a: *const i8, wd: *const i8, m: usize, kp: usize, n: usize) {
        unsafe {
            for mi in 0..m {
                let o = acc.add(mi * n);
                let ar = a.add(mi * kp);
                let mut j = 0;
                while j + 8 <= n {
                    let mut sl = vld1q_s32(o.add(j));
                    let mut sh = vld1q_s32(o.add(j + 4));
                    for r in 0..kp {
                        let w16 = vmovl_s8(vld1_s8(wd.add(r * n + j)));
                        let av = *ar.add(r) as i16;
                        sl = vmlal_n_s16(sl, vget_low_s16(w16), av);
                        sh = vmlal_n_s16(sh, vget_high_s16(w16), av);
                    }
                    vst1q_s32(o.add(j), sl);
                    vst1q_s32(o.add(j + 4), sh);
                    j += 8;
                }
                while j < n {
                    let mut s = *o.add(j);
                    for r in 0..kp {
                        s += *ar.add(r) as i32 * *wd.add(r * n + j) as i32;
                    }
                    *o.add(j) = s;
                    j += 1;
                }
            }
        }
    }
}

/// One layer's weights on the quantized path: the `[I+H, 4H]` matrix
/// packed as its two GEMM halves — input rows (`[I, 4H]`) and recurrent
/// rows (`[H, 4H]`), each with its own per-output-channel scales — plus
/// the f32 bias (biases are tiny and enter AFTER the integer GEMMs, at
/// requantization — quantizing them would only add error for zero win).
#[derive(Debug, Clone)]
pub struct QuantizedCellWeights {
    /// Input half: rows `0..I` of the combined matrix.
    pub wx: PackedQuantMatrix,
    /// Recurrent half: rows `I..I+H`.
    pub wh: PackedQuantMatrix,
    pub b: Vec<f32>,
    pub input_dim: usize,
    pub hidden: usize,
}

impl QuantizedCellWeights {
    /// Pack one f32 layer. The split mirrors the f32 cell's two
    /// `matmul_into` calls over the halves of `W`; quantization-wise it
    /// buys each half (and each activation kind) its own resolution.
    pub fn quantize(weights: &LstmCellWeights) -> Self {
        let n = 4 * weights.hidden;
        let split = weights.input_dim * n;
        Self {
            wx: PackedQuantMatrix::pack(&weights.w.data()[..split], weights.input_dim, n),
            wh: PackedQuantMatrix::pack(&weights.w.data()[split..], weights.hidden, n),
            b: weights.b.data().to_vec(),
            input_dim: weights.input_dim,
            hidden: weights.hidden,
        }
    }

    /// The larger of the two packed K extents (scratch sizing).
    pub fn k_padded_max(&self) -> usize {
        self.wx.k_padded.max(self.wh.k_padded)
    }
}

/// Quantize one f32 slice into an int8 row (symmetric, one dynamic
/// scale for the row), zeroing the quad-padding tail. Returns the
/// dequantization scale (`v ≈ q · scale`); an all-zero row returns
/// scale 0 with all-zero lanes.
fn quantize_row(part: &[f32], out: &mut [i8]) -> f32 {
    let mut amax = 0.0f32;
    for &v in part {
        amax = amax.max(v.abs());
    }
    if amax == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let inv = 127.0 / amax;
    for (&v, q) in part.iter().zip(out.iter_mut()) {
        *q = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    out[part.len()..].fill(0);
    amax / 127.0
}

/// Reusable buffers of the quantized step: the int8 activation staging
/// plane, the i32 accumulator plane and the per-row dequantization
/// scales. Owned by [`BatchArena`] (lazily sized — a pure-f32 arena
/// never allocates them) so steady-state quantized serving performs
/// zero heap allocations per step, same discipline as the f32 planes.
/// The buffers are plain row-major planes, so the intra-batch
/// partitioner can hand each worker a disjoint row range of all three
/// (see `step_rows_quant_slices`).
#[derive(Debug, Clone, Default)]
pub struct QuantScratch {
    /// `[rows, k_padded_max]` quantized `[x;h]` rows (padding lanes 0).
    pub qa: Vec<i8>,
    /// `[rows, 4H]` integer GEMM accumulator.
    pub qacc: Vec<i32>,
    /// One dynamic dequantization scale per batch row.
    pub qscale: Vec<f32>,
}

impl QuantScratch {
    /// Grow every buffer to hold `rows` rows (no-op when they fit).
    pub fn reserve(&mut self, rows: usize, k_padded_max: usize, gate_width: usize) {
        if self.qa.len() < rows * k_padded_max {
            self.qa.resize(rows * k_padded_max, 0);
        }
        if self.qacc.len() < rows * gate_width {
            self.qacc.resize(rows * gate_width, 0);
        }
        if self.qscale.len() < rows {
            self.qscale.resize(rows, 0.0);
        }
    }
}

/// One half of the quantized gate computation: quantize each row of
/// `act` (`[rows, k]` f32) with its own dynamic scale, run the integer
/// GEMM against `w`, and fold the dequantized contribution into
/// `gates`. `init` seeds each gate row from the bias (the x half);
/// otherwise contributions accumulate (the h half). Scratch arrives as
/// raw row-major slices so partitioned workers can pass disjoint
/// sub-planes.
#[allow(clippy::too_many_arguments)]
fn quant_gemm_half(
    w: &PackedQuantMatrix,
    act: &[f32],
    bias: &[f32],
    gates: &mut [f32],
    qa: &mut [i8],
    qacc: &mut [i32],
    qscale: &mut [f32],
    rows: usize,
    init: bool,
) {
    let k = w.k;
    let kp = w.k_padded;
    let n = w.n;
    debug_assert_eq!(act.len(), rows * k);
    debug_assert_eq!(gates.len(), rows * n);
    let qa = &mut qa[..rows * kp];
    let qacc = &mut qacc[..rows * n];
    let qscale = &mut qscale[..rows];

    for ((arow, qrow), s) in
        act.chunks_exact(k).zip(qa.chunks_exact_mut(kp)).zip(qscale.iter_mut())
    {
        *s = quantize_row(arow, qrow);
    }
    qacc.fill(0);
    quant_matmul_into(qacc, qa, w, rows);
    for ((grow, arow), &s_row) in
        gates.chunks_exact_mut(n).zip(qacc.chunks_exact(n)).zip(qscale.iter())
    {
        if init {
            for (((g, &acc), &b), &s_ch) in
                grow.iter_mut().zip(arow).zip(bias).zip(&w.scales)
            {
                *g = b + acc as f32 * (s_row * s_ch);
            }
        } else {
            for ((g, &acc), &s_ch) in grow.iter_mut().zip(arow).zip(&w.scales) {
                *g += acc as f32 * (s_row * s_ch);
            }
        }
    }
}

/// One quantized LSTM step for `rows` batch rows, in place: the int8
/// mirror of `plan::step_rows`. Reads `xs` (`[rows, I]`, f32),
/// overwrites `h`/`c` (`[rows, H]`, f32). `gates` is the same `[rows,
/// 4H]` f32 buffer the f32 path uses; `scratch` must be
/// [`QuantScratch::reserve`]d for `rows`.
///
/// Per step: two quantize → integer-GEMM → requantize passes (input
/// half seeding the gates from the bias, recurrent half accumulating —
/// the f32 cell's two `matmul_into` calls, mirrored), then the fused
/// point-wise tail through [`crate::lstm::tail::lstm_tail`].
pub fn step_rows_quant(
    weights: &QuantizedCellWeights,
    xs: &[f32],
    h: &mut [f32],
    c: &mut [f32],
    gates: &mut [f32],
    scratch: &mut QuantScratch,
    rows: usize,
) {
    step_rows_quant_slices(
        weights,
        xs,
        h,
        c,
        gates,
        &mut scratch.qa,
        &mut scratch.qacc,
        &mut scratch.qscale,
        rows,
    )
}

/// [`step_rows_quant`] over raw scratch slices — the entry point the
/// intra-batch partitioner uses, handing each worker a disjoint row
/// range of the arena's scratch planes. `qa`/`qacc`/`qscale` must hold
/// at least `rows * k_padded_max` / `rows * 4H` / `rows` elements.
#[allow(clippy::too_many_arguments)]
pub(crate) fn step_rows_quant_slices(
    weights: &QuantizedCellWeights,
    xs: &[f32],
    h: &mut [f32],
    c: &mut [f32],
    gates: &mut [f32],
    qa: &mut [i8],
    qacc: &mut [i32],
    qscale: &mut [f32],
    rows: usize,
) {
    let hid = weights.hidden;
    let in_dim = weights.input_dim;
    debug_assert_eq!(weights.wx.k, in_dim);
    debug_assert_eq!(weights.wh.k, hid);
    debug_assert_eq!(xs.len(), rows * in_dim);
    debug_assert_eq!(h.len(), rows * hid);
    debug_assert_eq!(c.len(), rows * hid);
    debug_assert!(gates.len() >= rows * 4 * hid);
    debug_assert!(qa.len() >= rows * weights.k_padded_max());
    debug_assert!(qacc.len() >= rows * 4 * hid);
    debug_assert!(qscale.len() >= rows);
    let gates = &mut gates[..rows * 4 * hid];

    quant_gemm_half(&weights.wx, xs, &weights.b, gates, qa, qacc, qscale, rows, true);
    quant_gemm_half(&weights.wh, h, &weights.b, gates, qa, qacc, qscale, rows, false);

    // Fused point-wise tail through the dispatch table — on SIMD hosts
    // bit-identical to the scalar fast_sigmoid/fast_tanh loop that lived
    // here before DESIGN.md §14 unified the tail; under the forced-scalar
    // ISA this is the exact libm oracle instead.
    crate::lstm::tail::lstm_tail(gates, h, c, rows, hid);
}

/// A fully packed model for the int8 path: quantized layer weights plus
/// the f32 classifier head (the head is one tiny `[H, C]` GEMV per
/// window — quantizing it would save nothing measurable and the logits
/// are the accuracy-bearing output).
#[derive(Debug, Clone)]
pub struct QuantizedLstmModel {
    pub shape: ModelShape,
    layers: Vec<QuantizedCellWeights>,
    w_out: Tensor,
    b_out: Tensor,
}

impl QuantizedLstmModel {
    pub fn new(
        shape: ModelShape,
        layers: Vec<QuantizedCellWeights>,
        w_out: Tensor,
        b_out: Tensor,
    ) -> Self {
        assert_eq!(layers.len(), shape.num_layers);
        Self { shape, layers, w_out, b_out }
    }

    pub fn layers(&self) -> &[QuantizedCellWeights] {
        &self.layers
    }

    /// Classify a `[B, T, D]` batch through the quantized time-major
    /// plan; returns `[B, C]` logits. Same driver contract as
    /// `LstmModel::forward_batch`, reusing the same [`BatchArena`].
    pub fn forward_batch_quant(&self, x: &Tensor, arena: &mut BatchArena) -> Tensor {
        let s = self.shape;
        assert_eq!(x.shape(), &[x.shape()[0], s.seq_len, s.input_dim]);
        let batch = x.shape()[0];
        let logits = self.forward_rows_quant(x.data(), batch, arena);
        Tensor::new(vec![batch, s.num_classes], logits)
    }

    /// Classify `rows` windows given as flat `[rows, T, D]` data through
    /// the quantized plan. The head runs in f32, accumulated in the same
    /// order as the f32 path's head.
    pub fn forward_rows_quant(
        &self,
        windows: &[f32],
        rows: usize,
        arena: &mut BatchArena,
    ) -> Vec<f32> {
        let s = self.shape;
        assert_eq!(arena.shape(), s, "arena built for a different model shape");
        let h_last = arena.run_quant(&self.layers, windows, rows);
        let mut logits = vec![0.0f32; rows * s.num_classes];
        for (hrow, lrow) in
            h_last.chunks_exact(s.hidden).zip(logits.chunks_exact_mut(s.num_classes))
        {
            self.head_into(hrow, lrow);
        }
        logits
    }

    /// The f32 classifier head for one `[H]` hidden row — same
    /// accumulation order as `LstmModel::head_into`, shared by the
    /// batched and streaming quant paths.
    pub(crate) fn head_into(&self, hrow: &[f32], lrow: &mut [f32]) {
        lrow.copy_from_slice(self.b_out.data());
        for (r, &hv) in hrow.iter().enumerate() {
            for (l, wv) in lrow.iter_mut().zip(self.w_out.row(r)) {
                *l += hv * wv;
            }
        }
    }

    /// Predicted class for one window under the crate-wide "first finite
    /// max" argmax rule — the quantized counterpart of
    /// `LstmModel::predict`.
    pub fn predict(&self, window: &[f32], arena: &mut BatchArena) -> usize {
        argmax_slice(&self.forward_rows_quant(window, 1, arena))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::{random_cell_weights, random_model};
    use crate::lstm::model::InferenceState;
    use crate::util::Rng;

    /// Naive i32 reference for the packed kernel.
    fn quant_matmul_naive(a: &[i8], w: &PackedQuantMatrix, m: usize) -> Vec<i32> {
        let (kp, n) = (w.k_padded, w.n);
        let mut out = vec![0i32; m * n];
        for mi in 0..m {
            for r in 0..kp {
                for j in 0..n {
                    out[mi * n + j] += a[mi * kp + r] as i32 * w.data()[r * n + j] as i32;
                }
            }
        }
        out
    }

    /// Random quad-zero-padded activation rows for kernel tests.
    fn random_activations(rng: &mut Rng, m: usize, k: usize, kp: usize) -> Vec<i8> {
        (0..m * kp)
            .map(|i| {
                // zero the lanes beyond k, as the driver guarantees
                if i % kp >= k {
                    0
                } else {
                    (rng.below(255) as i32 - 127) as i8
                }
            })
            .collect()
    }

    #[test]
    fn pack_pads_k_to_quads_with_zero_rows() {
        for &(k, n) in &[(1usize, 4usize), (4, 8), (5, 4), (7, 12), (41, 128)] {
            let mut rng = Rng::new(71);
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let p = PackedQuantMatrix::pack(&w, k, n);
            assert_eq!(p.k_padded % 4, 0);
            assert!(p.k_padded >= k && p.k_padded < k + 4);
            assert_eq!(p.data().len(), p.k_padded * n);
            assert!(p.data()[k * n..].iter().all(|&q| q == 0), "padding rows must be zero");
        }
    }

    #[test]
    fn pack_unpack_round_trip_within_half_step() {
        let mut rng = Rng::new(72);
        let (k, n) = (37, 64);
        let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let p = PackedQuantMatrix::pack(&w, k, n);
        let back = p.unpack();
        assert_eq!(back.len(), w.len());
        for (i, (&orig, &deq)) in w.iter().zip(&back).enumerate() {
            let s = p.scales[i % n];
            assert!(
                (orig - deq).abs() <= 0.5 * s + 1e-7,
                "elem {i}: |{orig} - {deq}| > s/2 = {}",
                0.5 * s
            );
        }
    }

    #[test]
    fn zero_channel_gets_zero_scale_and_zero_codes() {
        // Column 1 all-zero: scale 0, codes 0, dequantizes to exactly 0.
        let w = vec![0.5, 0.0, -0.25, 0.0, 1.0, 0.0];
        let p = PackedQuantMatrix::pack(&w, 3, 2);
        assert_eq!(p.scales[1], 0.0);
        let back = p.unpack();
        assert_eq!(back[1], 0.0);
        assert_eq!(back[3], 0.0);
        assert_eq!(back[5], 0.0);
    }

    #[test]
    fn quant_matmul_matches_naive_across_block_mixes() {
        let mut rng = Rng::new(73);
        // m covers quad/duo/single mixes; k covers padded and exact quads.
        for &(m, k, n) in &[
            (1usize, 5usize, 8usize),
            (2, 8, 12),
            (3, 9, 16),
            (4, 16, 8),
            (6, 41, 128),
            (7, 13, 20),
            (8, 64, 128),
            (9, 6, 7),
        ] {
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let p = PackedQuantMatrix::pack(&w, k, n);
            let a = random_activations(&mut rng, m, k, p.k_padded);
            let mut acc = vec![0i32; m * n];
            quant_matmul_into(&mut acc, &a, &p, m);
            assert_eq!(acc, quant_matmul_naive(&a, &p, m), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn dispatched_quant_matmul_is_bit_exact_with_scalar() {
        // Integer accumulation is associative: whatever ISA the dispatch
        // table selected must agree with the scalar oracle bit for bit —
        // including odd n (vector j-tail) and m tails.
        let mut rng = Rng::new(75);
        for &(m, k, n) in
            &[(1usize, 5usize, 17usize), (3, 12, 33), (5, 9, 16), (8, 64, 128), (9, 6, 7)]
        {
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let p = PackedQuantMatrix::pack(&w, k, n);
            let a = random_activations(&mut rng, m, k, p.k_padded);
            let mut disp = vec![0i32; m * n];
            let mut scal = vec![0i32; m * n];
            quant_matmul_into(&mut disp, &a, &p, m);
            quant_matmul_into_scalar(&mut scal, &a, &p, m);
            assert_eq!(disp, scal, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn quantize_row_zero_and_scaling() {
        let mut out = [0i8; 8];
        let s = quantize_row(&[0.0, 0.0, 0.0], &mut out);
        assert_eq!(s, 0.0);
        assert!(out.iter().all(|&q| q == 0));

        let s = quantize_row(&[1.0, -0.5, 0.25], &mut out);
        // amax = 1.0 -> scale 1/127; codes 127, -64 (round half away), 32.
        assert!((s - 1.0 / 127.0).abs() < 1e-9);
        assert_eq!(out[0], 127);
        assert_eq!(out[1], -64);
        assert_eq!(out[2], 32);
        assert!(out[3..].iter().all(|&q| q == 0), "padding lanes zeroed");
    }

    #[test]
    fn step_rows_quant_tracks_f32_step() {
        // One step of the quantized cell stays close to the f32 cell —
        // the per-step error budget the end-to-end parity test builds on.
        let mut rng = Rng::new(74);
        for &(rows, in_dim, hid) in &[(1usize, 9usize, 32usize), (5, 9, 32), (8, 3, 16)] {
            let w = random_cell_weights(&mut rng, in_dim, hid);
            let qw = QuantizedCellWeights::quantize(&w);
            let xs: Vec<f32> = (0..rows * in_dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let h0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c0: Vec<f32> = (0..rows * hid).map(|_| rng.uniform(-1.0, 1.0)).collect();

            let mut hq = h0.clone();
            let mut cq = c0.clone();
            let mut gates = vec![0.0f32; rows * 4 * hid];
            let mut scratch = QuantScratch::default();
            scratch.reserve(rows, qw.k_padded_max(), 4 * hid);
            step_rows_quant(&qw, &xs, &mut hq, &mut cq, &mut gates, &mut scratch, rows);

            let mut hf = h0.clone();
            let mut cf = c0.clone();
            let mut fgates = vec![0.0f32; rows * 4 * hid];
            crate::lstm::plan::step_rows(&w, &xs, &mut hf, &mut cf, &mut fgates, rows);

            for (i, (q, f)) in hq.iter().zip(&hf).enumerate() {
                assert!((q - f).abs() < 0.05, "h[{i}] drift {q} vs {f} ({rows},{in_dim},{hid})");
            }
            for (i, (q, f)) in cq.iter().zip(&cf).enumerate() {
                assert!((q - f).abs() < 0.08, "c[{i}] drift {q} vs {f} ({rows},{in_dim},{hid})");
            }
        }
    }

    #[test]
    fn forward_batch_quant_shapes_and_determinism() {
        let shape =
            ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 10, num_classes: 4 };
        let model = random_model(shape, 81);
        let qmodel = model.quantize();
        let mut rng = Rng::new(82);
        let data: Vec<f32> = (0..3 * 30).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = Tensor::new(vec![3, 10, 3], data);
        let mut arena = BatchArena::new(shape);
        let a = qmodel.forward_batch_quant(&x, &mut arena);
        assert_eq!(a.shape(), &[3, 4]);
        assert!(a.data().iter().all(|v| v.is_finite()));
        // Re-running through the reused arena is deterministic.
        let b = qmodel.forward_batch_quant(&x, &mut arena);
        assert_eq!(a, b);
    }

    #[test]
    fn quant_logits_near_f32_logits() {
        let shape = ModelShape::default();
        let model = random_model(shape, 83);
        let qmodel = model.quantize();
        let mut rng = Rng::new(84);
        let n = shape.seq_len * shape.input_dim;
        let mut arena = BatchArena::new(shape);
        let mut st = InferenceState::new(shape);
        for _ in 0..4 {
            let w: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let fl = model.forward_window(&w, &mut st);
            let ql = qmodel.forward_rows_quant(&w, 1, &mut arena);
            for (f, q) in fl.iter().zip(&ql) {
                assert!((f - q).abs() < 0.25, "logit drift {f} vs {q}");
            }
        }
    }
}
