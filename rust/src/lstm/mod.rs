//! Native Rust LSTM engine — the serving CPU path.
//!
//! This is the substrate the paper's CPU baselines run on: a from-scratch
//! stacked-LSTM forward pass whose numerics mirror the pure-jnp oracle
//! (`python/compile/kernels/ref.py`) bit-for-bit in layout and gate order
//! (i, g, f, o over a combined `[x;h] @ W + b` GEMM, forget bias 1.0).
//!
//! Two execution flavours:
//! - [`model::LstmModel::forward`] — single-threaded (paper's "CPU" bars)
//! - [`threaded::ThreadedLstm`]    — multi-threaded over the batch
//!   (paper §4.4's "multi-threaded RNN on the CPU")
//!
//! Weights come from MRNW files written by `python/compile/aot.py`
//! ([`weights`]), so the native engine and the PJRT artifact execute the
//! *same trained model* — cross-checked against golden logits in
//! `rust/tests/`.

pub mod cell;
pub mod model;
pub mod threaded;
pub mod weights;

pub use cell::{lstm_cell, LstmCellWeights, FORGET_BIAS};
pub use model::LstmModel;
pub use threaded::ThreadedLstm;
pub use weights::WeightFile;
