//! Native Rust LSTM engine — the serving CPU path.
//!
//! This is the substrate the paper's CPU baselines run on: a from-scratch
//! stacked-LSTM forward pass whose numerics mirror the pure-jnp oracle
//! (`python/compile/kernels/ref.py`) bit-for-bit in layout and gate order
//! (i, g, f, o over a combined `[x;h] @ W + b` GEMM, forget bias 1.0).
//!
//! Four execution flavours:
//! - [`model::LstmModel::forward_window`] — per-row GEMVs, one window at
//!   a time (paper's "CPU" bars; the parity oracle)
//! - [`model::LstmModel::forward_batch`] — the whole batch time-major
//!   through the preallocated [`plan::BatchArena`] execution plan
//!   (DESIGN.md §8), one blocked GEMM per `(t, layer)` step
//! - [`threaded::ThreadedLstm`]    — the batched plan data-parallelized
//!   over contiguous sub-batch chunks (paper §4.4's "multi-threaded RNN
//!   on the CPU"); within ONE batch, [`plan::PlanPool`] row-partitions
//!   the arena so single-batch engines scale with cores too (§13)
//! - [`quant::QuantizedLstmModel::forward_batch_quant`] — the batched
//!   plan on pre-packed int8 weights: integer GEMMs + fast rational
//!   tail, gated by argmax parity with the f32 oracle (DESIGN.md §10)
//! - [`model::LstmModel::stream_chunk`] /
//!   [`quant::QuantizedLstmModel::stream_chunk_quant`] — incremental
//!   per-step execution resuming from a persistent [`stream::StreamState`]
//!   (streaming sessions, DESIGN.md §11), bit-for-bit equal to the
//!   batched plan over the concatenated window
//!
//! Weights come from MRNW files written by `python/compile/aot.py`
//! ([`weights`]), so the native engine and the PJRT artifact execute the
//! *same trained model* — cross-checked against golden logits in
//! `rust/tests/`.

pub mod cell;
pub mod model;
pub mod plan;
pub mod quant;
pub mod stream;
pub mod tail;
pub mod threaded;
pub mod weights;

pub use cell::{lstm_cell, LstmCellWeights, FORGET_BIAS};
pub use model::LstmModel;
pub use plan::{chunk_spans, step_rows, BatchArena, PlanPool};
pub use quant::{
    fast_sigmoid, fast_tanh, QuantizedCellWeights, QuantizedLstmModel, SIGMOID_MAX_ABS_ERR,
    TANH_MAX_ABS_ERR,
};
pub use stream::StreamState;
pub use tail::{
    lstm_tail, lstm_tail_pade_scalar, lstm_tail_scalar, TAIL_C_MAX_ABS_ERR, TAIL_H_MAX_ABS_ERR,
};
pub use threaded::ThreadedLstm;
pub use weights::WeightFile;
