//! The `Engine` abstraction: one object-safe interface over every way an
//! inference can execute (DESIGN.md §3).
//!
//! MobiRNN's core claim is that *where* an inference runs is a runtime
//! policy, not a compile-time choice. The precondition (echoed by Lee et
//! al. 2019 and Rezk et al. 2019) is a uniform backend-delegate seam: the
//! router must not know that "GPU" means PJRT or that "CPU" means the
//! native Rust model. [`Engine`] is that seam; [`EngineRegistry`] maps an
//! offload [`Target`] to the engine serving it and provides the generic
//! failover path (PJRT error → next registered engine) that used to be a
//! hard-coded GPU→native special case in the router.
//!
//! All engines are pinned to the same trained weights and golden-tested
//! against the JAX oracle, so failover changes cost, never answers.
//!
//! At serving time the registry is spawned into [`EnginePools`]: one
//! executor worker (thread + bounded work queue) per registered engine,
//! so batches for different targets execute CONCURRENTLY instead of
//! head-of-line-blocking each other in the router thread (DESIGN.md §9).
//! Workers send [`ServeReply`]s directly; a pool-level failure
//! re-enqueues the batch on the next pool in failover order.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::{Manifest, ModelShape};
use crate::coordinator::device::DeviceState;
use crate::coordinator::health::{Admit, HealthRegistry};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::target_label;
use crate::coordinator::router::{ServeError, ServeReply, ServeRequest};
use crate::coordinator::router::{StreamReply, StreamRequest};
use crate::har::CLASS_NAMES;
use crate::lstm::{
    BatchArena, LstmModel, PlanPool, QuantizedLstmModel, StreamState, ThreadedLstm,
};
use crate::runtime::Runtime;
use crate::session::{SessionError, SessionStore};
use crate::simulator::{simulate_inference, Factorization, Target};
use crate::tensor::{argmax_slice, Tensor};

/// One execution backend. Object-safe so the router can hold a
/// heterogeneous `Target -> Box<dyn Engine>` registry.
pub trait Engine: Send {
    /// The offload target this engine serves (registry key; payload such
    /// as factorization or thread count is informational).
    fn target(&self) -> Target;

    /// Batch sizes this engine can execute, ascending. Empty slice means
    /// "any batch" (the native CPU engines); the PJRT engine is limited
    /// to the AOT-compiled variants.
    fn supported_batches(&self) -> &[usize];

    /// Run a `[B, T, D]` input; returns `[B, C]` logits.
    fn infer(&self, x: &Tensor) -> Result<Tensor>;

    /// Advance a streaming session's recurrent state through `steps`
    /// frames (`frames` is flat `[steps, I]`); returns flat `[steps, C]`
    /// per-step logits. Engines that cannot resume from external h/c
    /// state (the AOT PJRT artifacts are fixed-shape whole-window
    /// programs) keep the default, which errors — stream dispatch then
    /// fails over to a CPU pool.
    fn infer_stream(
        &self,
        frames: &[f32],
        steps: usize,
        state: &mut StreamState,
    ) -> Result<Vec<f32>> {
        let _ = (frames, steps, state);
        Err(anyhow!("engine {} does not support streaming sessions", self.label()))
    }

    /// Does this engine implement [`Engine::infer_stream`]? Session
    /// opens pin only to engines that say yes.
    fn supports_streaming(&self) -> bool {
        false
    }

    /// Human-readable name (wire protocol / metrics).
    fn label(&self) -> &'static str {
        target_label(self.target())
    }
}

/// Do two targets name the same engine kind (ignoring payload)?
pub fn same_kind(a: Target, b: Target) -> bool {
    matches!(
        (a, b),
        (Target::Gpu(_), Target::Gpu(_))
            | (Target::CpuSingle, Target::CpuSingle)
            | (Target::CpuMulti(_), Target::CpuMulti(_))
            | (Target::CpuQuant, Target::CpuQuant)
    )
}

/// May a request aimed at `target` fail over to an engine of
/// `candidate`'s kind? Failover normally changes cost, never answers —
/// every f32 engine is pinned to the same weights. The int8 engine
/// breaks that symmetry: its answers are approximate, so a batch that
/// did NOT ask for reduced precision must never land there. The
/// converse is allowed — an int8-target batch failing over to an f32
/// engine only gains fidelity (DESIGN.md §10).
fn failover_allowed(target: Target, candidate: Target) -> bool {
    !matches!(candidate, Target::CpuQuant) || matches!(target, Target::CpuQuant)
}

fn check_stream_shape(shape: ModelShape, frames: &[f32], steps: usize) -> Result<()> {
    if steps == 0 || frames.len() != steps * shape.input_dim {
        return Err(anyhow!(
            "stream chunk of {} floats is not [steps, {}] with steps >= 1",
            frames.len(),
            shape.input_dim
        ));
    }
    Ok(())
}

fn check_window_shape(shape: ModelShape, x: &Tensor) -> Result<usize> {
    let dims = x.shape();
    if dims.len() != 3 || dims[1] != shape.seq_len || dims[2] != shape.input_dim {
        return Err(anyhow!(
            "input shape {dims:?} does not match model [B, {}, {}]",
            shape.seq_len,
            shape.input_dim
        ));
    }
    Ok(dims[0])
}

/// GPU-target engine backed by the PJRT runtime's AOT-compiled variants.
pub struct PjrtEngine {
    runtime: Runtime,
    shape: ModelShape,
    batches: Vec<usize>,
}

impl PjrtEngine {
    /// Pre-compiles every batch variant for `shape` so serving never hits
    /// XLA compile on the hot path.
    pub fn new(manifest: &Manifest, runtime: Runtime, shape: ModelShape) -> Result<Self> {
        let batches = manifest.batches_for(shape);
        if batches.is_empty() {
            return Err(anyhow!(
                "no compiled variants for shape {shape:?}; run `make artifacts`"
            ));
        }
        for &b in &batches {
            runtime.preload(&shape.variant_name(b))?;
        }
        Ok(Self { runtime, shape, batches })
    }
}

impl Engine for PjrtEngine {
    fn target(&self) -> Target {
        Target::Gpu(Factorization::Coarse)
    }

    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        let batch = check_window_shape(self.shape, x)?;
        if !self.batches.contains(&batch) {
            return Err(anyhow!(
                "batch {batch} not AOT-compiled (have {:?})",
                self.batches
            ));
        }
        self.runtime.execute(&self.shape.variant_name(batch), x.clone())
    }
}

/// Single-threaded native CPU engine (the paper's "CPU" bars), executing
/// whole batches through the time-major plan (DESIGN.md §8) so the
/// batches the `BatchCollector` forms actually amortize weight traffic.
pub struct CpuSingleEngine {
    model: Arc<LstmModel>,
    /// Preallocated per-engine batch arena (§3.2 buffer reuse, batch-
    /// wide). `infer` takes `&self`, so the arena sits behind a mutex;
    /// the router worker is the only caller, so it is never contended.
    arena: Mutex<BatchArena>,
}

impl CpuSingleEngine {
    pub fn new(model: Arc<LstmModel>) -> Self {
        // Intra-batch pool (DESIGN.md §13): one batch's rows split across
        // the socket, so this engine scales with cores even at batch
        // size 1 per chunk. On a 1-core host the pool spawns no workers
        // and every run is plain inline execution.
        let pool = Arc::new(PlanPool::with_default_threads());
        let arena = Mutex::new(BatchArena::with_pool(model.shape, pool));
        Self { model, arena }
    }
}

impl Engine for CpuSingleEngine {
    fn target(&self) -> Target {
        Target::CpuSingle
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        check_window_shape(self.model.shape, x)?;
        let mut arena = self.arena.lock().unwrap();
        Ok(self.model.forward_batch(x, &mut arena))
    }

    fn infer_stream(
        &self,
        frames: &[f32],
        steps: usize,
        state: &mut StreamState,
    ) -> Result<Vec<f32>> {
        check_stream_shape(self.model.shape, frames, steps)?;
        Ok(self.model.stream_chunk(frames, steps, state))
    }

    fn supports_streaming(&self) -> bool {
        true
    }
}

/// Int8 quantized CPU engine (DESIGN.md §10): the batched time-major
/// plan over pre-packed per-output-channel int8 weights — integer
/// GEMMs, f32 requantization into the gate buffer, fast rational tail.
/// Registered alongside the f32 engines but entered only by explicit
/// request (`precision: int8` on the wire / `--precision int8`), never
/// by the offload policy or by another batch's failover
/// ([`failover_allowed`]): the path is approximate, gated by argmax
/// parity with the f32 oracle (`rust/tests/quant.rs`), and precision is
/// a caller-visible contract.
pub struct CpuQuantEngine {
    model: Arc<QuantizedLstmModel>,
    /// Preallocated per-engine batch arena (shared discipline with
    /// [`CpuSingleEngine`]); the pool worker is the only caller.
    arena: Mutex<BatchArena>,
}

impl CpuQuantEngine {
    pub fn new(model: Arc<QuantizedLstmModel>) -> Self {
        // Same intra-batch scaling as CpuSingleEngine (DESIGN.md §13).
        let pool = Arc::new(PlanPool::with_default_threads());
        let arena = Mutex::new(BatchArena::with_pool(model.shape, pool));
        Self { model, arena }
    }

    /// Pack an f32 model and build the engine over it (the common
    /// construction: quantization happens once, at registration).
    pub fn from_f32(model: &LstmModel) -> Self {
        Self::new(Arc::new(model.quantize()))
    }
}

impl Engine for CpuQuantEngine {
    fn target(&self) -> Target {
        Target::CpuQuant
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        check_window_shape(self.model.shape, x)?;
        let mut arena = self.arena.lock().unwrap();
        Ok(self.model.forward_batch_quant(x, &mut arena))
    }

    fn infer_stream(
        &self,
        frames: &[f32],
        steps: usize,
        state: &mut StreamState,
    ) -> Result<Vec<f32>> {
        check_stream_shape(self.model.shape, frames, steps)?;
        Ok(self.model.stream_chunk_quant(frames, steps, state))
    }

    fn supports_streaming(&self) -> bool {
        true
    }
}

/// Multi-threaded native CPU engine (paper §4.4) over a persistent
/// worker pool, chunking each batch across workers (DESIGN.md §8).
pub struct CpuMultiEngine {
    pool: ThreadedLstm,
    shape: ModelShape,
}

impl CpuMultiEngine {
    pub fn new(model: Arc<LstmModel>, threads: usize) -> Self {
        let shape = model.shape;
        Self { pool: ThreadedLstm::new(model, threads), shape }
    }
}

impl Engine for CpuMultiEngine {
    fn target(&self) -> Target {
        Target::CpuMulti(self.pool.num_threads)
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        check_window_shape(self.shape, x)?;
        Ok(self.pool.forward_batch(x))
    }

    fn infer_stream(
        &self,
        frames: &[f32],
        steps: usize,
        state: &mut StreamState,
    ) -> Result<Vec<f32>> {
        // One row gains nothing from fan-out: run the chunk on the
        // pool's shared model directly (same weights, same kernels).
        check_stream_shape(self.shape, frames, steps)?;
        Ok(self.pool.model().stream_chunk(frames, steps, state))
    }

    fn supports_streaming(&self) -> bool {
        true
    }
}

/// `Target -> Box<dyn Engine>` registry with generic failover.
///
/// Registration order is failover order: when the engine chosen by the
/// offload policy errors (or is absent), the remaining engines are tried
/// in the order they were registered.
#[derive(Default)]
pub struct EngineRegistry {
    engines: Vec<Box<dyn Engine>>,
}

impl EngineRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an engine; replaces any engine of the same target kind.
    pub fn register(&mut self, engine: Box<dyn Engine>) {
        if let Some(slot) =
            self.engines.iter_mut().find(|e| same_kind(e.target(), engine.target()))
        {
            *slot = engine;
        } else {
            self.engines.push(engine);
        }
    }

    /// The engine serving `target`'s kind, if any is registered.
    pub fn get(&self, target: Target) -> Option<&dyn Engine> {
        self.engines.iter().find(|e| same_kind(e.target(), target)).map(|e| &**e)
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Engine> {
        self.engines.iter().map(|e| &**e)
    }

    /// Registered targets, registration order.
    pub fn targets(&self) -> Vec<Target> {
        self.engines.iter().map(|e| e.target()).collect()
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Consume the registry into its engines, registration order (the
    /// transition from build-time collection to [`EnginePools`]).
    pub fn into_engines(self) -> Vec<Box<dyn Engine>> {
        self.engines
    }

    /// Execute `x` on the engine for `target`, failing over to every
    /// other registered engine in registration order.
    ///
    /// Returns `(outcome, engine_errors)` where `outcome` carries the
    /// logits plus the target that actually served the request, and
    /// `engine_errors` counts engines that errored along the way (for
    /// metrics) — on both success and total failure.
    ///
    /// When the engine of the requested kind serves the request, the
    /// *requested* target is returned, not `engine.target()`: payload
    /// like the GPU factorization or the simulated thread count is a
    /// policy decision the engine cannot know (the PJRT engine executes
    /// the same artifact for Fine and Coarse; only the latency model
    /// differs). On failover to a different kind the serving engine's
    /// own target is returned.
    pub fn infer_with_failover(
        &self,
        target: Target,
        x: &Tensor,
    ) -> (Result<(Tensor, Target)>, u64) {
        let mut errors = 0u64;
        if let Some(engine) = self.get(target) {
            match engine.infer(x) {
                Ok(logits) => return (Ok((logits, target)), errors),
                Err(e) => {
                    errors += 1;
                    eprintln!("[engine] {} failed, failing over: {e:#}", engine.label());
                }
            }
        }
        for engine in self
            .engines
            .iter()
            .filter(|e| !same_kind(e.target(), target) && failover_allowed(target, e.target()))
        {
            match engine.infer(x) {
                Ok(logits) => return (Ok((logits, engine.target())), errors),
                Err(e) => {
                    errors += 1;
                    eprintln!("[engine] {} failed, failing over: {e:#}", engine.label());
                }
            }
        }
        (
            Err(anyhow!(
                "all {} registered engines failed for target {target:?}",
                self.engines.len()
            )),
            errors,
        )
    }
}

impl std::fmt::Debug for EngineRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EngineRegistry").field("targets", &self.targets()).finish()
    }
}

// ---- engine pools (scheduler + per-engine workers, DESIGN.md §9) -----

/// One batch handed from the scheduler to an engine pool. Carries
/// everything the worker needs to execute and REPLY on its own: the
/// padded tensor, the member requests, the requested target (payload
/// preserved for latency simulation and wire labels) and the bitmask of
/// pools that already tried — and failed — to execute it.
pub(crate) struct BatchJob {
    pub x: Tensor,
    pub reqs: Vec<ServeRequest>,
    pub target: Target,
    pub padded_to: usize,
    pub tried: u32,
    /// Earliest member deadline — the retry budget every failover hop
    /// spends from (DESIGN.md §15). `None` = retry rounds stop after the
    /// first full sweep, preserving the legacy single-round semantics.
    pub deadline: Option<Instant>,
    /// Completed retry rounds; drives the capped exponential backoff.
    pub attempt: u32,
    /// `Some("int8")` when the scheduler brownout-downgraded this f32
    /// batch to the quant tier; stamped into every member reply.
    pub degraded: Option<&'static str>,
}

/// One streaming chunk handed from the scheduler to the pool a session
/// is pinned to. `target` is the affinity pin at dispatch time; when
/// failover lands the chunk on a different-kind pool, that worker
/// re-pins the session there and bumps `sessions_migrated` — the state
/// itself is engine-agnostic f32 in the session store, so migration is
/// a pointer update, never a copy (DESIGN.md §11).
pub(crate) struct StreamJob {
    pub req: StreamRequest,
    pub target: Target,
    pub tried: u32,
}

/// A message on a pool's work queue.
pub(crate) enum PoolMsg {
    Job(BatchJob),
    Stream(StreamJob),
    /// Drain-and-exit marker; queued jobs ahead of it still execute.
    Shutdown,
}

/// Cloneable handle to one engine's executor worker: the target it
/// serves plus the bounded sender feeding its queue.
#[derive(Clone)]
pub(crate) struct EnginePool {
    target: Target,
    tx: mpsc::SyncSender<PoolMsg>,
}

impl EnginePool {
    /// Try to hand `job` to this pool, keeping the in-flight gauge
    /// consistent: up BEFORE the send (so the worker's decrement can
    /// never be observed first), back down if the queue is full or the
    /// worker is gone. Returns the job on refusal. Shared by scheduler
    /// dispatch and worker failover so the gauge protocol lives in
    /// exactly one place.
    fn offer(&self, job: BatchJob, metrics: &Metrics) -> Result<(), BatchJob> {
        metrics.inflight.slot(self.target).fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(PoolMsg::Job(job)) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(m)) | Err(mpsc::TrySendError::Disconnected(m)) => {
                metrics.inflight.slot(self.target).fetch_sub(1, Ordering::Relaxed);
                let PoolMsg::Job(j) = m else { unreachable!("we only send jobs here") };
                Err(j)
            }
        }
    }

    /// [`Self::offer`] for stream chunks — same gauge protocol.
    fn offer_stream(&self, job: StreamJob, metrics: &Metrics) -> Result<(), StreamJob> {
        metrics.inflight.slot(self.target).fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(PoolMsg::Stream(job)) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(m)) | Err(mpsc::TrySendError::Disconnected(m)) => {
                metrics.inflight.slot(self.target).fetch_sub(1, Ordering::Relaxed);
                let PoolMsg::Stream(j) = m else { unreachable!("we only send stream jobs here") };
                Err(j)
            }
        }
    }
}

/// The spawned form of [`EngineRegistry`]: one worker thread + bounded
/// work queue per registered engine. The scheduler dispatches batches
/// here and moves on — execution, latency simulation, metrics and the
/// replies all happen on the pool worker, so batches for different
/// targets overlap in time. Failover order is registration order, same
/// as [`EngineRegistry::infer_with_failover`].
pub(crate) struct EnginePools {
    pools: Vec<EnginePool>,
    handles: Vec<std::thread::JoinHandle<()>>,
    health: Arc<HealthRegistry>,
    watchdog_stop: Arc<AtomicBool>,
    watchdog: Option<std::thread::JoinHandle<()>>,
}

/// What a pool worker is executing right now. The slot (one per worker)
/// is the watchdog protocol: the worker parks the job here for the
/// duration of the engine call, and whoever `take()`s it owns the job,
/// its replies, and the pool's in-flight gauge decrement. A worker that
/// finds its slot empty after the engine returns knows the watchdog
/// reclaimed the dispatch and discards the late result.
pub(crate) enum Active {
    Batch(BatchJob),
    Stream(StreamJob),
}

pub(crate) struct ActiveEntry {
    started: Instant,
    job: Active,
}

type ActiveSlot = Arc<Mutex<Option<ActiveEntry>>>;

/// Pool indices in dispatch order for `target`: the pool of the same
/// kind first (if any), then the rest in registration order — skipping
/// pools that [`failover_allowed`] forbids (a batch that did not ask
/// for int8 never lands on the quant pool).
fn pool_order(pools: &[EnginePool], target: Target) -> impl Iterator<Item = usize> + '_ {
    let primary = pools.iter().position(|p| same_kind(p.target, target));
    primary.into_iter().chain((0..pools.len()).filter(move |&i| {
        Some(i) != primary && failover_allowed(target, pools[i].target)
    }))
}

impl EnginePools {
    /// Spawn one executor worker per registered engine. `depth` bounds
    /// each pool's work queue (in batches); the scheduler's `try_send`
    /// fails instead of blocking when a pool is saturated.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn start(
        registry: EngineRegistry,
        device: DeviceState,
        metrics: Arc<Metrics>,
        sessions: Arc<SessionStore>,
        shape: ModelShape,
        depth: usize,
        health: Arc<HealthRegistry>,
        watchdog: Option<Duration>,
    ) -> Result<Self> {
        let engines = registry.into_engines();
        if engines.is_empty() {
            return Err(anyhow!("engine pools need at least one engine"));
        }
        debug_assert!(engines.len() <= 32, "tried-mask is a u32");
        debug_assert_eq!(health.len(), engines.len(), "health registry built for these pools");
        let depth = depth.max(1);
        let mut pools = Vec::with_capacity(engines.len());
        let mut rxs = Vec::with_capacity(engines.len());
        let mut slots: Vec<ActiveSlot> = Vec::with_capacity(engines.len());
        for engine in &engines {
            let (tx, rx) = mpsc::sync_channel(depth);
            pools.push(EnginePool { target: engine.target(), tx });
            rxs.push(rx);
            slots.push(Arc::new(Mutex::new(None)));
        }
        let mut handles = Vec::with_capacity(engines.len());
        for (index, (engine, rx)) in engines.into_iter().zip(rxs).enumerate() {
            let name = format!("mobirnn-pool-{}", engine.label());
            let worker = PoolWorker {
                index,
                engine,
                rx,
                peers: pools.clone(),
                device: device.clone(),
                metrics: Arc::clone(&metrics),
                sessions: Arc::clone(&sessions),
                shape,
                active: Arc::clone(&slots[index]),
                health: Arc::clone(&health),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(name)
                    .spawn(move || worker.run())
                    .context("spawning engine pool worker")?,
            );
        }
        let watchdog_stop = Arc::new(AtomicBool::new(false));
        let watchdog = match watchdog.filter(|t| !t.is_zero()) {
            Some(timeout) => {
                let stop = Arc::clone(&watchdog_stop);
                let pools = pools.clone();
                let metrics = Arc::clone(&metrics);
                let health = Arc::clone(&health);
                Some(
                    std::thread::Builder::new()
                        .name("mobirnn-watchdog".to_string())
                        .spawn(move || run_watchdog(slots, pools, metrics, health, timeout, stop))
                        .context("spawning dispatch watchdog")?,
                )
            }
            None => None,
        };
        Ok(Self { pools, handles, health, watchdog_stop, watchdog })
    }

    /// The health registry these pools report into.
    pub(crate) fn health(&self) -> &Arc<HealthRegistry> {
        &self.health
    }

    /// True when no pool eligible to serve `target` (same kind first,
    /// then failover order) could currently accept work — every breaker
    /// in the order is open inside its cooldown. The scheduler's
    /// brownout-or-shed gate (DESIGN.md §15).
    pub(crate) fn no_pool_available(&self, target: Target) -> bool {
        pool_order(&self.pools, target).all(|i| !self.health.dispatchable(i))
    }

    /// Is some pool of `t`'s kind admitting traffic? Used by the cost
    /// model to price breaker-open pools as infinite cost (they simply
    /// drop out of the candidate set).
    pub(crate) fn kind_dispatchable(&self, t: Target) -> bool {
        self.pools
            .iter()
            .enumerate()
            .any(|(i, p)| same_kind(p.target, t) && self.health.dispatchable(i))
    }

    /// Offer `job` to the pool serving its target's kind, then to every
    /// other pool in registration order. `Ok(())` once a queue accepted
    /// it; `Err(job)` when every pool is saturated (the caller keeps the
    /// requests queued — admission control sheds overflow, not this).
    pub(crate) fn dispatch(&self, mut job: BatchJob, metrics: &Metrics) -> Result<(), BatchJob> {
        for i in pool_order(&self.pools, job.target) {
            let Some(admit) = self.health.try_admit(i) else { continue };
            match self.pools[i].offer(job, metrics) {
                Ok(()) => return Ok(()),
                Err(j) => {
                    job = j;
                    if admit == Admit::Probe {
                        self.health.release_probe(i);
                    }
                }
            }
        }
        Err(job)
    }

    /// [`Self::dispatch`] for stream chunks: the pinned pool first, then
    /// the failover order (same precision rules — an f32 stream never
    /// lands on the quant pool).
    pub(crate) fn dispatch_stream(
        &self,
        mut job: StreamJob,
        metrics: &Metrics,
    ) -> Result<(), StreamJob> {
        for i in pool_order(&self.pools, job.target) {
            let Some(admit) = self.health.try_admit(i) else { continue };
            match self.pools[i].offer_stream(job, metrics) {
                Ok(()) => return Ok(()),
                Err(j) => {
                    job = j;
                    if admit == Admit::Probe {
                        self.health.release_probe(i);
                    }
                }
            }
        }
        Err(job)
    }

    /// Stop every worker: each pool finishes the jobs already queued,
    /// then honors the shutdown marker; joins happen after every marker
    /// is enqueued so cross-pool failover cannot deadlock the exit.
    pub(crate) fn shutdown(&mut self) {
        for pool in &self.pools {
            // Blocking send: queued jobs drain first. Err means the
            // worker is already gone, which is fine.
            let _ = pool.tx.send(PoolMsg::Shutdown);
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
        self.watchdog_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.watchdog.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for EnginePools {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-dispatch watchdog (DESIGN.md §15): scans every worker's active
/// slot and reclaims dispatches that have exceeded `timeout`. Reclaiming
/// takes the job out of the slot — from that point the watchdog owns the
/// replies and the gauge decrement, and the wedged worker's eventual
/// return is discarded. The pool's breaker is forced open (a wedged
/// worker is worse than an erroring one: its queue cannot drain), so new
/// traffic stays away until the cooldown probe.
///
/// Batches get one non-blocking handoff round to untried, admitted
/// pools; streams resolve to a typed error immediately — the wedged
/// worker may still hold the session's shard lock, so re-dispatching the
/// chunk could double-advance the state once the worker revives.
fn run_watchdog(
    slots: Vec<ActiveSlot>,
    pools: Vec<EnginePool>,
    metrics: Arc<Metrics>,
    health: Arc<HealthRegistry>,
    timeout: Duration,
    stop: Arc<AtomicBool>,
) {
    let tick = (timeout / 4).clamp(Duration::from_millis(1), Duration::from_millis(50));
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(tick);
        for (i, slot) in slots.iter().enumerate() {
            let stolen = {
                let mut s = slot.lock().unwrap();
                match s.as_ref() {
                    Some(entry) if entry.started.elapsed() >= timeout => s.take(),
                    _ => None,
                }
            };
            let Some(entry) = stolen else { continue };
            let overdue = entry.started.elapsed();
            metrics.watchdog_fired.fetch_add(1, Ordering::Relaxed);
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            metrics.inflight.slot(pools[i].target).fetch_sub(1, Ordering::Relaxed);
            health.force_open(i);
            eprintln!(
                "[watchdog] pool {i} exceeded {timeout:?} (running {overdue:?}); reclaiming",
            );
            match entry.job {
                Active::Batch(mut job) => {
                    job.tried |= 1 << i;
                    let err = anyhow!("watchdog: engine exceeded its {timeout:?} dispatch budget");
                    if let Err(job) = handoff_once(&pools, &health, &metrics, job) {
                        fail_batch_terminal(job, &metrics, err);
                    }
                }
                Active::Stream(job) => {
                    let _ = job.req.reply.send(Err(ServeError::EngineFailure(format!(
                        "watchdog: engine exceeded its {timeout:?} dispatch budget"
                    ))));
                }
            }
        }
    }
}

/// One non-blocking failover round: offer `job` to every untried,
/// breaker-admitted pool in failover order. `Ok(())` when a queue took
/// it (counted as a retry); `Err(job)` hands the batch back.
fn handoff_once(
    pools: &[EnginePool],
    health: &HealthRegistry,
    metrics: &Metrics,
    mut job: BatchJob,
) -> Result<(), BatchJob> {
    for i in pool_order(pools, job.target) {
        if job.tried & (1 << i) != 0 {
            continue;
        }
        let Some(admit) = health.try_admit(i) else { continue };
        match pools[i].offer(job, metrics) {
            Ok(()) => {
                metrics.retries.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
            Err(j) => {
                job = j;
                if admit == Admit::Probe {
                    health.release_probe(i);
                }
            }
        }
    }
    Err(job)
}

/// Terminal failure for a batch whose retry options ran out: typed
/// `RetriesExhausted` when a deadline budget was being spent, the legacy
/// `EngineFailure` otherwise. Every member gets exactly one reply.
fn fail_batch_terminal(job: BatchJob, metrics: &Metrics, err: anyhow::Error) {
    if job.deadline.is_some() {
        metrics.retries_exhausted.fetch_add(job.reqs.len() as u64, Ordering::Relaxed);
        for req in job.reqs {
            let _ = req.reply.send(Err(ServeError::RetriesExhausted));
        }
    } else {
        let msg = format!("all engine pools failed or were saturated (last: {err:#})");
        for req in job.reqs {
            let _ = req.reply.send(Err(ServeError::EngineFailure(msg.clone())));
        }
    }
}

/// One engine's executor: owns the engine, drains its queue, executes
/// batches and replies. On engine error it re-enqueues the batch on the
/// next untried pool (never blocking — a saturated or stopped peer is
/// skipped) and only fails the requests when no pool is left.
struct PoolWorker {
    index: usize,
    engine: Box<dyn Engine>,
    rx: mpsc::Receiver<PoolMsg>,
    peers: Vec<EnginePool>,
    device: DeviceState,
    metrics: Arc<Metrics>,
    sessions: Arc<SessionStore>,
    shape: ModelShape,
    /// This worker's watchdog slot (see [`Active`]).
    active: ActiveSlot,
    health: Arc<HealthRegistry>,
}

/// Base backoff for deadline-budgeted retries; doubles per attempt.
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(1);
/// Backoff growth cap — retries never sleep longer than this per round.
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(50);

impl PoolWorker {
    fn run(mut self) {
        while let Ok(msg) = self.rx.recv() {
            match msg {
                PoolMsg::Job(job) => self.execute(job),
                PoolMsg::Stream(job) => self.execute_stream(job),
                PoolMsg::Shutdown => break,
            }
        }
        // A peer can fail a batch over into this queue AFTER our
        // shutdown marker (failover-during-shutdown): fail those
        // requests loudly instead of dropping their reply senders, and
        // keep the in-flight gauge balanced. (A forward landing after
        // this drain still gets a channel-disconnect error at the
        // caller, never a hang.)
        while let Ok(msg) = self.rx.try_recv() {
            match msg {
                PoolMsg::Job(job) => {
                    self.metrics
                        .inflight
                        .slot(self.engine.target())
                        .fetch_sub(1, Ordering::Relaxed);
                    let reason = "engine pools shut down before this batch could run".to_string();
                    for req in job.reqs {
                        let _ = req.reply.send(Err(ServeError::EngineFailure(reason.clone())));
                    }
                }
                PoolMsg::Stream(job) => {
                    self.metrics
                        .inflight
                        .slot(self.engine.target())
                        .fetch_sub(1, Ordering::Relaxed);
                    let _ = job.req.reply.send(Err(ServeError::EngineFailure(
                        "engine pools shut down before this stream chunk could run".to_string(),
                    )));
                }
                PoolMsg::Shutdown => {}
            }
        }
    }

    fn execute(&mut self, job: BatchJob) {
        let kind = self.engine.target();
        let t0 = Instant::now();
        // Park the job in the watchdog slot for the duration of the
        // engine call: whoever takes it back owns replies + gauge.
        let x = job.x.clone();
        *self.active.lock().unwrap() =
            Some(ActiveEntry { started: t0, job: Active::Batch(job) });
        let outcome = self.engine.infer(&x);
        let entry = self.active.lock().unwrap().take();
        let Some(ActiveEntry { job: Active::Batch(mut job), .. }) = entry else {
            // The watchdog reclaimed this dispatch while the engine ran;
            // the result is late and no longer ours to report.
            return;
        };
        self.metrics.inflight.slot(kind).fetch_sub(1, Ordering::Relaxed);
        match outcome {
            Ok(logits) => {
                self.health.on_success(self.index, t0.elapsed().as_nanos() as u64);
                // Same-kind execution preserves the REQUESTED payload
                // (factorization / simulated thread count are policy
                // attributes); cross-kind failover reports the engine's
                // own target. Mirrors `infer_with_failover`.
                let used = if same_kind(job.target, kind) { job.target } else { kind };
                let compute_ns = t0.elapsed().as_nanos() as u64;
                complete_batch(
                    job,
                    &logits,
                    used,
                    compute_ns,
                    &self.device,
                    &self.metrics,
                    self.shape,
                );
            }
            Err(e) => {
                self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                self.health.on_failure(self.index);
                eprintln!(
                    "[pool] {} failed, re-enqueueing on next pool: {e:#}",
                    self.engine.label()
                );
                job.tried |= 1 << self.index;
                self.fail_over(job, e);
            }
        }
    }

    /// One stream chunk: advance the pinned session's h/c under its
    /// shard lock, reply with per-step logits. Session lookup happens
    /// HERE, not at dispatch — TTL applies for the whole queued wait,
    /// and the worker that actually executes owns the expiry metrics.
    fn execute_stream(&mut self, job: StreamJob) {
        let kind = self.engine.target();
        let t0 = Instant::now();
        let now_ns = self.sessions.now_ns();
        // Copy what the engine call needs, then park the job (with its
        // reply sink) in the watchdog slot — same protocol as `execute`.
        let session_id = job.req.session;
        let frames = job.req.frames.clone();
        let steps = job.req.steps;
        *self.active.lock().unwrap() =
            Some(ActiveEntry { started: t0, job: Active::Stream(job) });
        let engine = &self.engine;
        let outcome = self.sessions.with(session_id, now_ns, |sess| {
            let r = engine.infer_stream(&frames, steps, &mut sess.state);
            if r.is_ok() {
                // Session-layer step tally: holds for any engine
                // implementation, echoed to the client on close.
                sess.steps += steps as u64;
            }
            r
        });
        let entry = self.active.lock().unwrap().take();
        let Some(ActiveEntry { job: Active::Stream(mut job), .. }) = entry else {
            // Watchdog reclaimed the chunk; it already replied with a
            // typed error. Note the state advance (if the engine
            // eventually succeeded) still happened under the shard lock.
            return;
        };
        self.metrics.inflight.slot(kind).fetch_sub(1, Ordering::Relaxed);
        match outcome {
            Err(SessionError::NotFound(id)) => {
                let _ = job.req.reply.send(Err(ServeError::SessionNotFound(id)));
            }
            Err(SessionError::Expired(id)) => {
                self.metrics.sessions_expired.fetch_add(1, Ordering::Relaxed);
                self.metrics.sessions_open.fetch_sub(1, Ordering::Relaxed);
                let _ = job.req.reply.send(Err(ServeError::SessionExpired(id)));
            }
            Ok(Err(e)) => {
                self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                self.health.on_failure(self.index);
                eprintln!(
                    "[pool] {} stream failed, re-enqueueing on next pool: {e:#}",
                    self.engine.label()
                );
                job.tried |= 1 << self.index;
                self.fail_over_stream(job, e);
            }
            Ok(Ok(logits)) => {
                self.health.on_success(self.index, t0.elapsed().as_nanos() as u64);
                // Cross-kind failover served this chunk: the state (f32,
                // engine-agnostic, already advanced under the shard
                // lock) migrates by re-pinning the session here.
                if !same_kind(job.target, kind) && self.sessions.set_target(job.req.session, kind)
                {
                    self.metrics.sessions_migrated.fetch_add(1, Ordering::Relaxed);
                }
                let used = if same_kind(job.target, kind) { job.target } else { kind };
                let compute_ns = t0.elapsed().as_nanos() as u64;
                complete_stream(job, logits, used, compute_ns, &self.metrics, self.shape);
            }
        }
    }

    fn fail_over_stream(&self, mut job: StreamJob, err: anyhow::Error) {
        for i in pool_order(&self.peers, job.target) {
            if job.tried & (1 << i) != 0 {
                continue;
            }
            let Some(admit) = self.health.try_admit(i) else { continue };
            match self.peers[i].offer_stream(job, &self.metrics) {
                Ok(()) => {
                    self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Err(j) => {
                    job = j;
                    if admit == Admit::Probe {
                        self.health.release_probe(i);
                    }
                }
            }
        }
        let msg = format!("all engine pools failed or were saturated (last: {err:#})");
        let _ = job.req.reply.send(Err(ServeError::EngineFailure(msg)));
    }

    /// Deadline-budgeted retry (DESIGN.md §15). Each round offers the
    /// batch to every untried, breaker-admitted pool; a round that lands
    /// nowhere either terminates (no deadline: legacy single-round
    /// semantics; budget spent: typed `retries_exhausted`) or sleeps a
    /// capped exponential backoff, clears the tried mask, and sweeps
    /// again. The budget check charges the backoff BEFORE sleeping, so a
    /// request can never oversleep its own deadline here — the watchdog
    /// grace is the only slack on top.
    fn fail_over(&self, mut job: BatchJob, err: anyhow::Error) {
        loop {
            match handoff_once(&self.peers, &self.health, &self.metrics, job) {
                Ok(()) => return,
                Err(j) => job = j,
            }
            let Some(deadline) = job.deadline else {
                return fail_batch_terminal(job, &self.metrics, err);
            };
            job.attempt = job.attempt.saturating_add(1);
            let backoff = RETRY_BACKOFF_BASE
                .saturating_mul(1u32 << (job.attempt - 1).min(16))
                .min(RETRY_BACKOFF_CAP);
            if Instant::now() + backoff >= deadline {
                return fail_batch_terminal(job, &self.metrics, err);
            }
            std::thread::sleep(backoff);
            // A fresh round may retry pools that failed earlier — the
            // breaker, not the tried mask, now decides who is touchable.
            job.tried = 0;
        }
    }
}

/// Success tail of a stream chunk: metrics plus one [`StreamReply`]
/// carrying per-step classes and logits. Streams skip the simulated
/// batch-latency accounting — the DES models whole-window kernel
/// launches, not single-row incremental steps; wall/compute histograms
/// and dispatch counters still record.
fn complete_stream(
    job: StreamJob,
    logits: Vec<f32>,
    used: Target,
    compute_ns: u64,
    metrics: &Metrics,
    shape: ModelShape,
) {
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    metrics.compute_latency.record(compute_ns);
    match used {
        Target::Gpu(_) => metrics.gpu_dispatches.fetch_add(1, Ordering::Relaxed),
        _ => metrics.cpu_dispatches.fetch_add(1, Ordering::Relaxed),
    };
    let wall_ns = Instant::now().duration_since(job.req.enqueued).as_nanos() as u64;
    metrics.wall_latency.record(wall_ns);
    let classes = logits.chunks_exact(shape.num_classes).map(argmax_slice).collect();
    let _ = job.req.reply.send(Ok(StreamReply {
        id: job.req.id,
        session: job.req.session,
        steps: job.req.steps,
        classes,
        logits,
        wall_ns,
        target: target_label(used),
    }));
}

/// Success tail of a batch: simulated-device accounting, metrics, and
/// one [`ServeReply`] per member request — everything the old router
/// thread did after the engine returned, now on the pool worker.
fn complete_batch(
    job: BatchJob,
    logits: &Tensor,
    used: Target,
    compute_ns: u64,
    device: &DeviceState,
    metrics: &Metrics,
    shape: ModelShape,
) {
    // SIMULATED device latency. The paper's measurement is CLOSED-LOOP
    // (inferences run back-to-back on the phone), so each GPU batch's
    // device time elapses on the virtual clock before this pool's next
    // batch: enqueue + advance drains the queue exactly, keeping
    // sim_ns = work_ns for sequential batches while still charging
    // queueing delay when dispatches overlap.
    let util = match used {
        Target::Gpu(_) => device.gpu_util(),
        _ => device.cpu_util(),
    };
    let work_ns = simulate_inference(device.profile(), shape, job.padded_to, used, util);
    let sim_ns = match used {
        Target::Gpu(_) => {
            let latency = device.enqueue_gpu(work_ns);
            device.advance_virtual(work_ns);
            latency
        }
        _ => work_ns,
    };

    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.requests.fetch_add(job.reqs.len() as u64, Ordering::Relaxed);
    metrics.padded_slots.fetch_add((job.padded_to - job.reqs.len()) as u64, Ordering::Relaxed);
    metrics.compute_latency.record(compute_ns);
    metrics.sim_latency.record(sim_ns);
    match used {
        Target::Gpu(_) => metrics.gpu_dispatches.fetch_add(1, Ordering::Relaxed),
        _ => metrics.cpu_dispatches.fetch_add(1, Ordering::Relaxed),
    };

    let done = Instant::now();
    let batch_size = job.padded_to;
    if job.degraded.is_some() {
        metrics.degraded.fetch_add(job.reqs.len() as u64, Ordering::Relaxed);
    }
    for (i, req) in job.reqs.into_iter().enumerate() {
        let wall_ns = done.duration_since(req.enqueued).as_nanos() as u64;
        metrics.wall_latency.record(wall_ns);
        let row = logits.row(i).to_vec();
        // NaN-robust "first finite max" rule (tensor.rs) — a broken
        // engine must yield a defined class, never a panic in the pool.
        let class = argmax_slice(&row);
        let _ = req.reply.send(Ok(ServeReply {
            id: req.opts.id,
            class,
            label: CLASS_NAMES.get(class).unwrap_or(&"?").to_string(),
            logits: row,
            wall_ns,
            sim_ns,
            target: target_label(used),
            batch_size,
            degraded: job.degraded,
        }));
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Deterministic engine for tests: always predicts class 1 (or fails
    /// on demand). No artifacts needed.
    pub(crate) struct FixedEngine {
        pub target: Target,
        pub batches: Vec<usize>,
        pub fail: bool,
        pub num_classes: usize,
        pub calls: Arc<AtomicUsize>,
    }

    impl FixedEngine {
        pub(crate) fn new(target: Target) -> Self {
            Self {
                target,
                batches: Vec::new(),
                fail: false,
                num_classes: 6,
                calls: Arc::new(AtomicUsize::new(0)),
            }
        }

        pub(crate) fn failing(target: Target) -> Self {
            Self { fail: true, ..Self::new(target) }
        }
    }

    impl Engine for FixedEngine {
        fn target(&self) -> Target {
            self.target
        }

        fn supported_batches(&self) -> &[usize] {
            &self.batches
        }

        fn infer(&self, x: &Tensor) -> Result<Tensor> {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if self.fail {
                return Err(anyhow!("FixedEngine({}) told to fail", self.label()));
            }
            let batch = x.shape()[0];
            let mut data = vec![0.0f32; batch * self.num_classes];
            for i in 0..batch {
                data[i * self.num_classes + 1] = 1.0;
            }
            Ok(Tensor::new(vec![batch, self.num_classes], data))
        }

        fn infer_stream(
            &self,
            _frames: &[f32],
            steps: usize,
            _state: &mut StreamState,
        ) -> Result<Vec<f32>> {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if self.fail {
                return Err(anyhow!("FixedEngine({}) told to fail", self.label()));
            }
            let mut data = vec![0.0f32; steps * self.num_classes];
            for t in 0..steps {
                data[t * self.num_classes + 1] = 1.0;
            }
            Ok(data)
        }

        fn supports_streaming(&self) -> bool {
            true
        }
    }

    /// Engine that sleeps `delay` per batch and records each execution's
    /// wall-clock span — the fixture for proving that batches on
    /// different pools overlap in time.
    pub(crate) struct SlowEngine {
        pub target: Target,
        pub delay: std::time::Duration,
        pub spans: Arc<Mutex<Vec<(Instant, Instant)>>>,
    }

    impl SlowEngine {
        pub(crate) fn new(target: Target, delay: std::time::Duration) -> Self {
            Self { target, delay, spans: Arc::new(Mutex::new(Vec::new())) }
        }
    }

    impl Engine for SlowEngine {
        fn target(&self) -> Target {
            self.target
        }

        fn supported_batches(&self) -> &[usize] {
            &[]
        }

        fn infer(&self, x: &Tensor) -> Result<Tensor> {
            let start = Instant::now();
            std::thread::sleep(self.delay);
            let batch = x.shape()[0];
            let mut data = vec![0.0f32; batch * 6];
            for i in 0..batch {
                data[i * 6 + 1] = 1.0;
            }
            self.spans.lock().unwrap().push((start, Instant::now()));
            Ok(Tensor::new(vec![batch, 6], data))
        }
    }

    /// Engine that emits NaN-poisoned logits: `[NaN, 1.0, 7.0, 0.5,
    /// NaN, 0.0]` per row. Under the "first finite max" rule the class
    /// must come out as 2 — and never panic the pool worker.
    pub(crate) struct NanEngine {
        pub target: Target,
    }

    impl NanEngine {
        pub(crate) fn new(target: Target) -> Self {
            Self { target }
        }
    }

    impl Engine for NanEngine {
        fn target(&self) -> Target {
            self.target
        }

        fn supported_batches(&self) -> &[usize] {
            &[]
        }

        fn infer(&self, x: &Tensor) -> Result<Tensor> {
            let batch = x.shape()[0];
            let row = [f32::NAN, 1.0, 7.0, 0.5, f32::NAN, 0.0];
            let mut data = Vec::with_capacity(batch * 6);
            for _ in 0..batch {
                data.extend_from_slice(&row);
            }
            Ok(Tensor::new(vec![batch, 6], data))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::FixedEngine;
    use super::*;
    use std::sync::atomic::Ordering;

    fn x(batch: usize) -> Tensor {
        Tensor::zeros(vec![batch, 128, 9])
    }

    #[test]
    fn same_kind_ignores_payload() {
        assert!(same_kind(Target::Gpu(Factorization::Fine), Target::Gpu(Factorization::Coarse)));
        assert!(same_kind(Target::CpuMulti(2), Target::CpuMulti(8)));
        assert!(same_kind(Target::CpuQuant, Target::CpuQuant));
        assert!(!same_kind(Target::CpuSingle, Target::CpuMulti(1)));
        assert!(!same_kind(Target::Gpu(Factorization::Coarse), Target::CpuSingle));
        assert!(!same_kind(Target::CpuQuant, Target::CpuSingle));
    }

    #[test]
    fn quant_engine_never_receives_failover_traffic() {
        // An f32-target batch must NOT land on the int8 engine when its
        // own engine fails — failover may change cost, never answers.
        let mut reg = EngineRegistry::new();
        let quant = FixedEngine::new(Target::CpuQuant);
        let quant_calls = Arc::clone(&quant.calls);
        reg.register(Box::new(FixedEngine::failing(Target::CpuSingle)));
        reg.register(Box::new(quant));
        let (outcome, errors) = reg.infer_with_failover(Target::CpuSingle, &x(1));
        assert!(outcome.is_err(), "quant is not an acceptable f32 substitute");
        assert_eq!(errors, 1);
        assert_eq!(quant_calls.load(Ordering::Relaxed), 0, "quant engine must stay untouched");
    }

    #[test]
    fn quant_target_fails_over_to_f32() {
        // The converse is allowed: failing over int8 -> f32 only gains
        // fidelity.
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::failing(Target::CpuQuant)));
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        let (outcome, errors) = reg.infer_with_failover(Target::CpuQuant, &x(1));
        let (_, used) = outcome.unwrap();
        assert_eq!(used, Target::CpuSingle);
        assert_eq!(errors, 1);
    }

    #[test]
    fn cpu_quant_engine_serves_batches() {
        let shape = crate::config::ModelShape {
            num_layers: 1,
            hidden: 4,
            input_dim: 3,
            seq_len: 10,
            num_classes: 6,
        };
        let model = crate::bench::random_model(shape, 5);
        let engine = CpuQuantEngine::from_f32(&model);
        assert_eq!(engine.target(), Target::CpuQuant);
        assert_eq!(engine.label(), "cpu-quant");
        let logits = engine.infer(&Tensor::zeros(vec![2, 10, 3])).unwrap();
        assert_eq!(logits.shape(), &[2, 6]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
        assert!(engine.infer(&Tensor::zeros(vec![1, 9, 3])).is_err(), "shape checked");
    }

    #[test]
    fn registry_lookup_by_kind() {
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::new(Target::Gpu(Factorization::Coarse))));
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        assert_eq!(reg.len(), 2);
        // Any factorization resolves to the one GPU engine.
        assert!(reg.get(Target::Gpu(Factorization::Fine)).is_some());
        assert!(reg.get(Target::CpuSingle).is_some());
        assert!(reg.get(Target::CpuMulti(4)).is_none());
    }

    #[test]
    fn register_replaces_same_kind() {
        let mut reg = EngineRegistry::new();
        let first = FixedEngine::new(Target::CpuMulti(2));
        let first_calls = Arc::clone(&first.calls);
        reg.register(Box::new(first));
        reg.register(Box::new(FixedEngine::new(Target::CpuMulti(8))));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.targets(), vec![Target::CpuMulti(8)]);
        let (outcome, _) = reg.infer_with_failover(Target::CpuMulti(8), &x(1));
        outcome.unwrap();
        assert_eq!(first_calls.load(Ordering::Relaxed), 0, "replaced engine must not run");
    }

    #[test]
    fn served_target_preserves_requested_payload() {
        // The policy's payload (factorization, simulated thread count) is
        // a decision attribute: when the same-kind engine serves the
        // request, the requested target comes back unchanged so latency
        // simulation and wire labels stay faithful (Fine vs Coarse!).
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::new(Target::Gpu(Factorization::Coarse))));
        let (outcome, errors) = reg.infer_with_failover(Target::Gpu(Factorization::Fine), &x(1));
        let (_, used) = outcome.unwrap();
        assert_eq!(used, Target::Gpu(Factorization::Fine));
        assert_eq!(errors, 0);
    }

    #[test]
    fn failover_to_next_engine_on_error() {
        let mut reg = EngineRegistry::new();
        let gpu = FixedEngine::failing(Target::Gpu(Factorization::Coarse));
        let gpu_calls = Arc::clone(&gpu.calls);
        reg.register(Box::new(gpu));
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        let (outcome, errors) =
            reg.infer_with_failover(Target::Gpu(Factorization::Coarse), &x(2));
        let (logits, used) = outcome.unwrap();
        assert_eq!(used, Target::CpuSingle);
        assert_eq!(errors, 1);
        assert_eq!(gpu_calls.load(Ordering::Relaxed), 1);
        assert_eq!(logits.shape(), &[2, 6]);
    }

    #[test]
    fn missing_primary_uses_first_compatible_without_error() {
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        let (outcome, errors) =
            reg.infer_with_failover(Target::Gpu(Factorization::Coarse), &x(1));
        let (_, used) = outcome.unwrap();
        assert_eq!(used, Target::CpuSingle);
        assert_eq!(errors, 0, "absent engine is not an execution error");
    }

    #[test]
    fn all_engines_failing_is_an_error_with_count() {
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::failing(Target::CpuSingle)));
        reg.register(Box::new(FixedEngine::failing(Target::CpuMulti(4))));
        let (outcome, errors) = reg.infer_with_failover(Target::CpuSingle, &x(1));
        let err = outcome.unwrap_err();
        assert!(err.to_string().contains("all 2"), "{err}");
        assert_eq!(errors, 2, "every tried engine counts as one error");
    }

    #[test]
    fn empty_registry_errors() {
        let reg = EngineRegistry::new();
        assert!(reg.is_empty());
        let (outcome, errors) = reg.infer_with_failover(Target::CpuSingle, &x(1));
        assert!(outcome.is_err());
        assert_eq!(errors, 0);
    }
}
