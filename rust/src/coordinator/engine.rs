//! The `Engine` abstraction: one object-safe interface over every way an
//! inference can execute (DESIGN.md §3).
//!
//! MobiRNN's core claim is that *where* an inference runs is a runtime
//! policy, not a compile-time choice. The precondition (echoed by Lee et
//! al. 2019 and Rezk et al. 2019) is a uniform backend-delegate seam: the
//! router must not know that "GPU" means PJRT or that "CPU" means the
//! native Rust model. [`Engine`] is that seam; [`EngineRegistry`] maps an
//! offload [`Target`] to the engine serving it and provides the generic
//! failover path (PJRT error → next registered engine) that used to be a
//! hard-coded GPU→native special case in the router.
//!
//! All engines are pinned to the same trained weights and golden-tested
//! against the JAX oracle, so failover changes cost, never answers.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::config::{Manifest, ModelShape};
use crate::coordinator::policy::target_label;
use crate::lstm::{BatchArena, LstmModel, ThreadedLstm};
use crate::runtime::Runtime;
use crate::simulator::{Factorization, Target};
use crate::tensor::Tensor;

/// One execution backend. Object-safe so the router can hold a
/// heterogeneous `Target -> Box<dyn Engine>` registry.
pub trait Engine: Send {
    /// The offload target this engine serves (registry key; payload such
    /// as factorization or thread count is informational).
    fn target(&self) -> Target;

    /// Batch sizes this engine can execute, ascending. Empty slice means
    /// "any batch" (the native CPU engines); the PJRT engine is limited
    /// to the AOT-compiled variants.
    fn supported_batches(&self) -> &[usize];

    /// Run a `[B, T, D]` input; returns `[B, C]` logits.
    fn infer(&self, x: &Tensor) -> Result<Tensor>;

    /// Human-readable name (wire protocol / metrics).
    fn label(&self) -> &'static str {
        target_label(self.target())
    }
}

/// Do two targets name the same engine kind (ignoring payload)?
pub fn same_kind(a: Target, b: Target) -> bool {
    matches!(
        (a, b),
        (Target::Gpu(_), Target::Gpu(_))
            | (Target::CpuSingle, Target::CpuSingle)
            | (Target::CpuMulti(_), Target::CpuMulti(_))
    )
}

fn check_window_shape(shape: ModelShape, x: &Tensor) -> Result<usize> {
    let dims = x.shape();
    if dims.len() != 3 || dims[1] != shape.seq_len || dims[2] != shape.input_dim {
        return Err(anyhow!(
            "input shape {dims:?} does not match model [B, {}, {}]",
            shape.seq_len,
            shape.input_dim
        ));
    }
    Ok(dims[0])
}

/// GPU-target engine backed by the PJRT runtime's AOT-compiled variants.
pub struct PjrtEngine {
    runtime: Runtime,
    shape: ModelShape,
    batches: Vec<usize>,
}

impl PjrtEngine {
    /// Pre-compiles every batch variant for `shape` so serving never hits
    /// XLA compile on the hot path.
    pub fn new(manifest: &Manifest, runtime: Runtime, shape: ModelShape) -> Result<Self> {
        let batches = manifest.batches_for(shape);
        if batches.is_empty() {
            return Err(anyhow!(
                "no compiled variants for shape {shape:?}; run `make artifacts`"
            ));
        }
        for &b in &batches {
            runtime.preload(&shape.variant_name(b))?;
        }
        Ok(Self { runtime, shape, batches })
    }
}

impl Engine for PjrtEngine {
    fn target(&self) -> Target {
        Target::Gpu(Factorization::Coarse)
    }

    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        let batch = check_window_shape(self.shape, x)?;
        if !self.batches.contains(&batch) {
            return Err(anyhow!(
                "batch {batch} not AOT-compiled (have {:?})",
                self.batches
            ));
        }
        self.runtime.execute(&self.shape.variant_name(batch), x.clone())
    }
}

/// Single-threaded native CPU engine (the paper's "CPU" bars), executing
/// whole batches through the time-major plan (DESIGN.md §8) so the
/// batches the `BatchCollector` forms actually amortize weight traffic.
pub struct CpuSingleEngine {
    model: Arc<LstmModel>,
    /// Preallocated per-engine batch arena (§3.2 buffer reuse, batch-
    /// wide). `infer` takes `&self`, so the arena sits behind a mutex;
    /// the router worker is the only caller, so it is never contended.
    arena: Mutex<BatchArena>,
}

impl CpuSingleEngine {
    pub fn new(model: Arc<LstmModel>) -> Self {
        let arena = Mutex::new(BatchArena::new(model.shape));
        Self { model, arena }
    }
}

impl Engine for CpuSingleEngine {
    fn target(&self) -> Target {
        Target::CpuSingle
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        check_window_shape(self.model.shape, x)?;
        let mut arena = self.arena.lock().unwrap();
        Ok(self.model.forward_batch(x, &mut arena))
    }
}

/// Multi-threaded native CPU engine (paper §4.4) over a persistent
/// worker pool, chunking each batch across workers (DESIGN.md §8).
pub struct CpuMultiEngine {
    pool: ThreadedLstm,
    shape: ModelShape,
}

impl CpuMultiEngine {
    pub fn new(model: Arc<LstmModel>, threads: usize) -> Self {
        let shape = model.shape;
        Self { pool: ThreadedLstm::new(model, threads), shape }
    }
}

impl Engine for CpuMultiEngine {
    fn target(&self) -> Target {
        Target::CpuMulti(self.pool.num_threads)
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        check_window_shape(self.shape, x)?;
        Ok(self.pool.forward_batch(x))
    }
}

/// `Target -> Box<dyn Engine>` registry with generic failover.
///
/// Registration order is failover order: when the engine chosen by the
/// offload policy errors (or is absent), the remaining engines are tried
/// in the order they were registered.
#[derive(Default)]
pub struct EngineRegistry {
    engines: Vec<Box<dyn Engine>>,
}

impl EngineRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an engine; replaces any engine of the same target kind.
    pub fn register(&mut self, engine: Box<dyn Engine>) {
        if let Some(slot) =
            self.engines.iter_mut().find(|e| same_kind(e.target(), engine.target()))
        {
            *slot = engine;
        } else {
            self.engines.push(engine);
        }
    }

    /// The engine serving `target`'s kind, if any is registered.
    pub fn get(&self, target: Target) -> Option<&dyn Engine> {
        self.engines.iter().find(|e| same_kind(e.target(), target)).map(|e| &**e)
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Engine> {
        self.engines.iter().map(|e| &**e)
    }

    /// Registered targets, registration order.
    pub fn targets(&self) -> Vec<Target> {
        self.engines.iter().map(|e| e.target()).collect()
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Execute `x` on the engine for `target`, failing over to every
    /// other registered engine in registration order.
    ///
    /// Returns `(outcome, engine_errors)` where `outcome` carries the
    /// logits plus the target that actually served the request, and
    /// `engine_errors` counts engines that errored along the way (for
    /// metrics) — on both success and total failure.
    ///
    /// When the engine of the requested kind serves the request, the
    /// *requested* target is returned, not `engine.target()`: payload
    /// like the GPU factorization or the simulated thread count is a
    /// policy decision the engine cannot know (the PJRT engine executes
    /// the same artifact for Fine and Coarse; only the latency model
    /// differs). On failover to a different kind the serving engine's
    /// own target is returned.
    pub fn infer_with_failover(
        &self,
        target: Target,
        x: &Tensor,
    ) -> (Result<(Tensor, Target)>, u64) {
        let mut errors = 0u64;
        if let Some(engine) = self.get(target) {
            match engine.infer(x) {
                Ok(logits) => return (Ok((logits, target)), errors),
                Err(e) => {
                    errors += 1;
                    eprintln!("[engine] {} failed, failing over: {e:#}", engine.label());
                }
            }
        }
        for engine in self.engines.iter().filter(|e| !same_kind(e.target(), target)) {
            match engine.infer(x) {
                Ok(logits) => return (Ok((logits, engine.target())), errors),
                Err(e) => {
                    errors += 1;
                    eprintln!("[engine] {} failed, failing over: {e:#}", engine.label());
                }
            }
        }
        (
            Err(anyhow!(
                "all {} registered engines failed for target {target:?}",
                self.engines.len()
            )),
            errors,
        )
    }
}

impl std::fmt::Debug for EngineRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EngineRegistry").field("targets", &self.targets()).finish()
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Deterministic engine for tests: always predicts class 1 (or fails
    /// on demand). No artifacts needed.
    pub(crate) struct FixedEngine {
        pub target: Target,
        pub batches: Vec<usize>,
        pub fail: bool,
        pub num_classes: usize,
        pub calls: Arc<AtomicUsize>,
    }

    impl FixedEngine {
        pub(crate) fn new(target: Target) -> Self {
            Self {
                target,
                batches: Vec::new(),
                fail: false,
                num_classes: 6,
                calls: Arc::new(AtomicUsize::new(0)),
            }
        }

        pub(crate) fn failing(target: Target) -> Self {
            Self { fail: true, ..Self::new(target) }
        }
    }

    impl Engine for FixedEngine {
        fn target(&self) -> Target {
            self.target
        }

        fn supported_batches(&self) -> &[usize] {
            &self.batches
        }

        fn infer(&self, x: &Tensor) -> Result<Tensor> {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if self.fail {
                return Err(anyhow!("FixedEngine({}) told to fail", self.label()));
            }
            let batch = x.shape()[0];
            let mut data = vec![0.0f32; batch * self.num_classes];
            for i in 0..batch {
                data[i * self.num_classes + 1] = 1.0;
            }
            Ok(Tensor::new(vec![batch, self.num_classes], data))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::FixedEngine;
    use super::*;
    use std::sync::atomic::Ordering;

    fn x(batch: usize) -> Tensor {
        Tensor::zeros(vec![batch, 128, 9])
    }

    #[test]
    fn same_kind_ignores_payload() {
        assert!(same_kind(Target::Gpu(Factorization::Fine), Target::Gpu(Factorization::Coarse)));
        assert!(same_kind(Target::CpuMulti(2), Target::CpuMulti(8)));
        assert!(!same_kind(Target::CpuSingle, Target::CpuMulti(1)));
        assert!(!same_kind(Target::Gpu(Factorization::Coarse), Target::CpuSingle));
    }

    #[test]
    fn registry_lookup_by_kind() {
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::new(Target::Gpu(Factorization::Coarse))));
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        assert_eq!(reg.len(), 2);
        // Any factorization resolves to the one GPU engine.
        assert!(reg.get(Target::Gpu(Factorization::Fine)).is_some());
        assert!(reg.get(Target::CpuSingle).is_some());
        assert!(reg.get(Target::CpuMulti(4)).is_none());
    }

    #[test]
    fn register_replaces_same_kind() {
        let mut reg = EngineRegistry::new();
        let first = FixedEngine::new(Target::CpuMulti(2));
        let first_calls = Arc::clone(&first.calls);
        reg.register(Box::new(first));
        reg.register(Box::new(FixedEngine::new(Target::CpuMulti(8))));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.targets(), vec![Target::CpuMulti(8)]);
        let (outcome, _) = reg.infer_with_failover(Target::CpuMulti(8), &x(1));
        outcome.unwrap();
        assert_eq!(first_calls.load(Ordering::Relaxed), 0, "replaced engine must not run");
    }

    #[test]
    fn served_target_preserves_requested_payload() {
        // The policy's payload (factorization, simulated thread count) is
        // a decision attribute: when the same-kind engine serves the
        // request, the requested target comes back unchanged so latency
        // simulation and wire labels stay faithful (Fine vs Coarse!).
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::new(Target::Gpu(Factorization::Coarse))));
        let (outcome, errors) = reg.infer_with_failover(Target::Gpu(Factorization::Fine), &x(1));
        let (_, used) = outcome.unwrap();
        assert_eq!(used, Target::Gpu(Factorization::Fine));
        assert_eq!(errors, 0);
    }

    #[test]
    fn failover_to_next_engine_on_error() {
        let mut reg = EngineRegistry::new();
        let gpu = FixedEngine::failing(Target::Gpu(Factorization::Coarse));
        let gpu_calls = Arc::clone(&gpu.calls);
        reg.register(Box::new(gpu));
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        let (outcome, errors) =
            reg.infer_with_failover(Target::Gpu(Factorization::Coarse), &x(2));
        let (logits, used) = outcome.unwrap();
        assert_eq!(used, Target::CpuSingle);
        assert_eq!(errors, 1);
        assert_eq!(gpu_calls.load(Ordering::Relaxed), 1);
        assert_eq!(logits.shape(), &[2, 6]);
    }

    #[test]
    fn missing_primary_uses_first_compatible_without_error() {
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::new(Target::CpuSingle)));
        let (outcome, errors) =
            reg.infer_with_failover(Target::Gpu(Factorization::Coarse), &x(1));
        let (_, used) = outcome.unwrap();
        assert_eq!(used, Target::CpuSingle);
        assert_eq!(errors, 0, "absent engine is not an execution error");
    }

    #[test]
    fn all_engines_failing_is_an_error_with_count() {
        let mut reg = EngineRegistry::new();
        reg.register(Box::new(FixedEngine::failing(Target::CpuSingle)));
        reg.register(Box::new(FixedEngine::failing(Target::CpuMulti(4))));
        let (outcome, errors) = reg.infer_with_failover(Target::CpuSingle, &x(1));
        let err = outcome.unwrap_err();
        assert!(err.to_string().contains("all 2"), "{err}");
        assert_eq!(errors, 2, "every tried engine counts as one error");
    }

    #[test]
    fn empty_registry_errors() {
        let reg = EngineRegistry::new();
        assert!(reg.is_empty());
        let (outcome, errors) = reg.infer_with_failover(Target::CpuSingle, &x(1));
        assert!(outcome.is_err());
        assert_eq!(errors, 0);
    }
}
