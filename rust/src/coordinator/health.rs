//! Per-engine health tracking and circuit breaking (DESIGN.md §15).
//!
//! Every engine pool gets a [`PoolHealth`] record: an EWMA of observed
//! compute latency plus a consecutive-failure counter driving a
//! three-state circuit breaker:
//!
//! ```text
//!            failures >= threshold                cooldown elapsed
//!  Closed ───────────────────────────▶ Open ───────────────────────▶ HalfOpen
//!    ▲                                  ▲                               │
//!    │ probe succeeds                   │ probe fails                   │
//!    └──────────────────────────────────┴───────────────◀──────────────┘
//! ```
//!
//! The scheduler consults [`HealthRegistry::dispatchable`] before
//! dispatch (an open pool prices as infinite cost — it is simply removed
//! from the candidate set), and [`EnginePools`](super::engine::EnginePools)
//! calls [`HealthRegistry::try_admit`] per offer: a half-open breaker
//! admits exactly one probe batch at a time, whose outcome decides
//! whether the breaker closes or snaps back open. Every transition
//! increments a metrics counter (`breaker_open` / `breaker_half_open` /
//! `breaker_closed`) and is logged to stderr, so chaos tests can assert
//! the exact transition schedule a seeded fault plan produces.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;

/// Breaker tuning knobs (see `RouterBuilder::breaker`).
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive failures that trip a closed breaker open.
    pub failure_threshold: u32,
    /// How long an open breaker blocks traffic before granting a probe.
    pub cooldown: Duration,
    /// EWMA smoothing factor for observed compute latency, in `(0, 1]`.
    pub ewma_alpha: f64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            failure_threshold: 5,
            cooldown: Duration::from_secs(1),
            ewma_alpha: 0.2,
        }
    }
}

/// Circuit-breaker state for one engine pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: all traffic admitted.
    Closed,
    /// Tripped: no traffic until `cooldown` elapses.
    Open,
    /// Recovering: exactly one probe batch in flight at a time.
    HalfOpen,
}

/// What [`HealthRegistry::try_admit`] granted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Closed breaker: normal dispatch.
    Normal,
    /// Half-open breaker: this dispatch is the probe. If it never
    /// reaches the engine (queue refusal), release it with
    /// [`HealthRegistry::release_probe`].
    Probe,
}

#[derive(Debug)]
struct PoolHealth {
    state: BreakerState,
    consecutive_failures: u32,
    /// EWMA of observed per-batch compute latency; 0 until first sample.
    ewma_ns: f64,
    /// When the breaker last opened (drives the cooldown).
    opened_at: Option<Instant>,
    probe_inflight: bool,
}

impl PoolHealth {
    fn new() -> Self {
        PoolHealth {
            state: BreakerState::Closed,
            consecutive_failures: 0,
            ewma_ns: 0.0,
            opened_at: None,
            probe_inflight: false,
        }
    }
}

/// Health records for every registered engine pool, indexed in pool
/// registration order (the same indices as the `tried` bitmask).
pub struct HealthRegistry {
    config: BreakerConfig,
    labels: Vec<&'static str>,
    pools: Vec<Mutex<PoolHealth>>,
    metrics: Arc<Metrics>,
}

impl HealthRegistry {
    pub fn new(labels: Vec<&'static str>, config: BreakerConfig, metrics: Arc<Metrics>) -> Self {
        let pools = labels.iter().map(|_| Mutex::new(PoolHealth::new())).collect();
        HealthRegistry { config, labels, pools, metrics }
    }

    pub fn len(&self) -> usize {
        self.pools.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pools.is_empty()
    }

    pub fn state(&self, i: usize) -> BreakerState {
        self.pools[i].lock().unwrap().state
    }

    /// EWMA compute latency for pool `i`; 0 until the first success.
    pub fn ewma_ns(&self, i: usize) -> u64 {
        self.pools[i].lock().unwrap().ewma_ns as u64
    }

    /// True when any breaker is not closed — the scheduler uses this to
    /// bypass the decision cache (breaker state is not in its key).
    pub fn any_non_closed(&self) -> bool {
        self.pools.iter().any(|p| p.lock().unwrap().state != BreakerState::Closed)
    }

    /// Could pool `i` plausibly accept work now? Side-effect free: an
    /// open breaker inside its cooldown is the only "no". Half-open with
    /// a probe already in flight still counts as available — the batch
    /// will requeue and retry, not shed.
    pub fn dispatchable(&self, i: usize) -> bool {
        let h = self.pools[i].lock().unwrap();
        match h.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                h.opened_at.map_or(true, |at| at.elapsed() >= self.config.cooldown)
            }
        }
    }

    /// Gate one dispatch to pool `i`. `None` means the breaker refuses;
    /// `Some(Admit::Probe)` means the caller holds the half-open probe
    /// slot and must resolve it via the engine outcome (or
    /// [`Self::release_probe`] if the offer never reached the queue).
    pub fn try_admit(&self, i: usize) -> Option<Admit> {
        let mut h = self.pools[i].lock().unwrap();
        match h.state {
            BreakerState::Closed => Some(Admit::Normal),
            BreakerState::Open => {
                let cooled = h.opened_at.map_or(true, |at| at.elapsed() >= self.config.cooldown);
                if !cooled {
                    return None;
                }
                self.transition(&mut h, i, BreakerState::HalfOpen);
                h.probe_inflight = true;
                Some(Admit::Probe)
            }
            BreakerState::HalfOpen => {
                if h.probe_inflight {
                    return None;
                }
                h.probe_inflight = true;
                Some(Admit::Probe)
            }
        }
    }

    /// Return an unused probe slot (the offer was refused before the
    /// engine saw it, so the probe proved nothing).
    pub fn release_probe(&self, i: usize) {
        self.pools[i].lock().unwrap().probe_inflight = false;
    }

    /// Record a successful dispatch and its compute latency.
    pub fn on_success(&self, i: usize, compute_ns: u64) {
        let mut h = self.pools[i].lock().unwrap();
        h.consecutive_failures = 0;
        h.probe_inflight = false;
        let a = self.config.ewma_alpha;
        h.ewma_ns = if h.ewma_ns == 0.0 {
            compute_ns as f64
        } else {
            a * compute_ns as f64 + (1.0 - a) * h.ewma_ns
        };
        if h.state != BreakerState::Closed {
            self.transition(&mut h, i, BreakerState::Closed);
            h.opened_at = None;
        }
    }

    /// Record a failed dispatch; may trip the breaker open.
    pub fn on_failure(&self, i: usize) {
        let mut h = self.pools[i].lock().unwrap();
        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
        h.probe_inflight = false;
        let trip = match h.state {
            // A failed probe snaps straight back open.
            BreakerState::HalfOpen => true,
            BreakerState::Closed => h.consecutive_failures >= self.config.failure_threshold,
            BreakerState::Open => false,
        };
        if trip {
            self.transition(&mut h, i, BreakerState::Open);
            h.opened_at = Some(Instant::now());
        }
    }

    /// Trip the breaker open immediately (watchdog reclaim: the pool's
    /// worker is known to be wedged, not merely erroring).
    pub fn force_open(&self, i: usize) {
        let mut h = self.pools[i].lock().unwrap();
        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
        h.probe_inflight = false;
        if h.state != BreakerState::Open {
            self.transition(&mut h, i, BreakerState::Open);
        }
        h.opened_at = Some(Instant::now());
    }

    fn transition(&self, h: &mut PoolHealth, i: usize, to: BreakerState) {
        let counter = match to {
            BreakerState::Open => &self.metrics.breaker_open,
            BreakerState::HalfOpen => &self.metrics.breaker_half_open,
            BreakerState::Closed => &self.metrics.breaker_closed,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "[health] {} breaker {:?} -> {:?} (consecutive_failures={}, ewma={}us)",
            self.labels[i],
            h.state,
            to,
            h.consecutive_failures,
            (h.ewma_ns / 1_000.0) as u64,
        );
        h.state = to;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(threshold: u32, cooldown: Duration) -> HealthRegistry {
        let config = BreakerConfig {
            failure_threshold: threshold,
            cooldown,
            ..BreakerConfig::default()
        };
        HealthRegistry::new(vec!["cpu", "cpu-multi"], config, Arc::new(Metrics::new()))
    }

    #[test]
    fn breaker_trips_after_consecutive_failures_only() {
        let reg = registry(3, Duration::from_secs(60));
        reg.on_failure(0);
        reg.on_failure(0);
        reg.on_success(0, 1_000);
        reg.on_failure(0);
        reg.on_failure(0);
        assert_eq!(reg.state(0), BreakerState::Closed, "success resets the streak");
        reg.on_failure(0);
        assert_eq!(reg.state(0), BreakerState::Open);
        assert!(!reg.dispatchable(0));
        assert!(reg.try_admit(0).is_none(), "open + cold: no traffic");
        assert_eq!(reg.state(1), BreakerState::Closed, "per-pool isolation");
        assert!(reg.any_non_closed());
    }

    #[test]
    fn half_open_admits_one_probe_then_closes_on_success() {
        let reg = registry(1, Duration::from_millis(0));
        reg.on_failure(0);
        assert_eq!(reg.state(0), BreakerState::Open);
        // Cooldown of zero: the next admit becomes the probe.
        assert_eq!(reg.try_admit(0), Some(Admit::Probe));
        assert_eq!(reg.state(0), BreakerState::HalfOpen);
        assert!(reg.try_admit(0).is_none(), "one probe at a time");
        assert!(reg.dispatchable(0), "half-open batches requeue, not shed");
        reg.on_success(0, 2_000);
        assert_eq!(reg.state(0), BreakerState::Closed);
        assert_eq!(reg.try_admit(0), Some(Admit::Normal));
    }

    #[test]
    fn failed_probe_snaps_back_open_and_released_probe_frees_the_slot() {
        let reg = registry(1, Duration::from_millis(0));
        reg.on_failure(0);
        assert_eq!(reg.try_admit(0), Some(Admit::Probe));
        reg.on_failure(0);
        assert_eq!(reg.state(0), BreakerState::Open);

        // A probe that never reached the engine must free the slot.
        assert_eq!(reg.try_admit(0), Some(Admit::Probe));
        assert!(reg.try_admit(0).is_none());
        reg.release_probe(0);
        assert_eq!(reg.try_admit(0), Some(Admit::Probe));
    }

    #[test]
    fn transition_counters_count_every_edge() {
        let metrics = Arc::new(Metrics::new());
        let config = BreakerConfig {
            failure_threshold: 1,
            cooldown: Duration::from_millis(0),
            ..BreakerConfig::default()
        };
        let reg = HealthRegistry::new(vec!["cpu"], config, Arc::clone(&metrics));
        reg.on_failure(0); // closed -> open
        let _ = reg.try_admit(0); // open -> half-open (probe)
        reg.on_failure(0); // half-open -> open
        let _ = reg.try_admit(0); // open -> half-open (probe)
        reg.on_success(0, 1_000); // half-open -> closed
        assert_eq!(metrics.breaker_open.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.breaker_half_open.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.breaker_closed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn force_open_trips_immediately_and_ewma_tracks_latency() {
        let reg = registry(100, Duration::from_secs(60));
        reg.on_success(0, 1_000);
        assert_eq!(reg.ewma_ns(0), 1_000);
        reg.on_success(0, 2_000);
        assert_eq!(reg.ewma_ns(0), 1_200, "alpha 0.2 blend");
        reg.force_open(0);
        assert_eq!(reg.state(0), BreakerState::Open);
        assert!(!reg.dispatchable(0));
    }
}
