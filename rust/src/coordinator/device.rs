//! Shared simulated-device state.
//!
//! The coordinator serves on this host, but latency accounting happens
//! against the simulated phone (DESIGN.md §2). `DeviceState` is the
//! bridge: it holds the device profile, the current background GPU/CPU
//! utilizations (settable at runtime — the server's `set_load` command,
//! the Fig 7 sweeps) and a virtual GPU-queue horizon so concurrent
//! batches queue behind each other like they would on one mobile GPU.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::simulator::DeviceProfile;

/// Thread-safe simulated device.
#[derive(Debug, Clone)]
pub struct DeviceState {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    profile: DeviceProfile,
    /// Background GPU utilization ×1e6 (atomic fixed-point).
    gpu_util_micros: AtomicU64,
    /// Background CPU utilization ×1e6.
    cpu_util_micros: AtomicU64,
    /// Virtual time (ns) until which the simulated GPU queue is busy.
    gpu_busy_until_ns: AtomicU64,
    /// Monotonic virtual clock origin for the queue.
    virtual_now_ns: AtomicU64,
}

impl DeviceState {
    pub fn new(profile: DeviceProfile) -> Self {
        Self {
            inner: Arc::new(Inner {
                profile,
                gpu_util_micros: AtomicU64::new(0),
                cpu_util_micros: AtomicU64::new(0),
                gpu_busy_until_ns: AtomicU64::new(0),
                virtual_now_ns: AtomicU64::new(0),
            }),
        }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.inner.profile
    }

    pub fn set_gpu_util(&self, util: f64) {
        let v = (util.clamp(0.0, 1.0) * 1e6) as u64;
        self.inner.gpu_util_micros.store(v, Ordering::Relaxed);
    }

    pub fn set_cpu_util(&self, util: f64) {
        let v = (util.clamp(0.0, 1.0) * 1e6) as u64;
        self.inner.cpu_util_micros.store(v, Ordering::Relaxed);
    }

    pub fn gpu_util(&self) -> f64 {
        self.inner.gpu_util_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn cpu_util(&self) -> f64 {
        self.inner.cpu_util_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Advance the virtual clock by real elapsed time (called by the
    /// router between batches so the GPU queue drains realistically).
    pub fn advance_virtual(&self, dt_ns: u64) {
        self.inner.virtual_now_ns.fetch_add(dt_ns, Ordering::Relaxed);
    }

    /// Enqueue `work_ns` of simulated GPU work; returns the *total*
    /// latency including time queued behind earlier work — the mobile
    /// GPU is a single in-order queue.
    pub fn enqueue_gpu(&self, work_ns: u64) -> u64 {
        let now = self.inner.virtual_now_ns.load(Ordering::Relaxed);
        // CAS loop: start at max(now, busy_until), finish at start + work.
        loop {
            let busy = self.inner.gpu_busy_until_ns.load(Ordering::Relaxed);
            let start = busy.max(now);
            let finish = start + work_ns;
            if self
                .inner
                .gpu_busy_until_ns
                .compare_exchange(busy, finish, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return finish - now;
            }
        }
    }

    /// Current queue depth in ns (0 when idle).
    pub fn gpu_queue_ns(&self) -> u64 {
        let now = self.inner.virtual_now_ns.load(Ordering::Relaxed);
        self.inner.gpu_busy_until_ns.load(Ordering::Relaxed).saturating_sub(now)
    }

    /// Effective GPU utilization the policy sees: background render load
    /// plus pressure from our own queued work (queue > one frame counts
    /// as busy time).
    pub fn effective_gpu_util(&self) -> f64 {
        let frame = self.inner.profile.frame_period_ns() as f64;
        let queue_pressure = (self.gpu_queue_ns() as f64 / (4.0 * frame)).min(0.5);
        (self.gpu_util() + queue_pressure).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> DeviceState {
        DeviceState::new(DeviceProfile::nexus5())
    }

    #[test]
    fn util_set_get_clamped() {
        let d = state();
        d.set_gpu_util(0.42);
        assert!((d.gpu_util() - 0.42).abs() < 1e-6);
        d.set_gpu_util(7.0);
        assert_eq!(d.gpu_util(), 1.0);
        d.set_cpu_util(-1.0);
        assert_eq!(d.cpu_util(), 0.0);
    }

    #[test]
    fn gpu_queue_serializes_work() {
        let d = state();
        let l1 = d.enqueue_gpu(1_000_000);
        let l2 = d.enqueue_gpu(1_000_000);
        assert_eq!(l1, 1_000_000);
        assert_eq!(l2, 2_000_000, "second batch queues behind the first");
        assert_eq!(d.gpu_queue_ns(), 2_000_000);
    }

    #[test]
    fn queue_drains_with_virtual_time() {
        let d = state();
        d.enqueue_gpu(1_000_000);
        d.advance_virtual(600_000);
        assert_eq!(d.gpu_queue_ns(), 400_000);
        d.advance_virtual(600_000);
        assert_eq!(d.gpu_queue_ns(), 0);
        // After draining, new work starts fresh.
        let l = d.enqueue_gpu(500_000);
        assert_eq!(l, 500_000);
    }

    #[test]
    fn effective_util_includes_queue_pressure() {
        let d = state();
        d.set_gpu_util(0.3);
        let base = d.effective_gpu_util();
        assert!((base - 0.3).abs() < 1e-6);
        d.enqueue_gpu(200_000_000); // deep queue
        assert!(d.effective_gpu_util() > base + 0.4);
        assert!(d.effective_gpu_util() <= 1.0);
    }

    #[test]
    fn concurrent_enqueues_never_overlap() {
        use std::sync::Arc;
        let d = state();
        let total: u64 = 16 * 250_000;
        let mut handles = Vec::new();
        for _ in 0..4 {
            let d = d.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..4 {
                    d.enqueue_gpu(250_000);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Sum of all work is reflected exactly once in the horizon.
        assert_eq!(d.gpu_queue_ns(), total);
        let _ = Arc::strong_count(&d.inner);
    }
}
