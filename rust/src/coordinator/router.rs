//! The serving loop: queue → batch → offload decision → engine → reply.
//!
//! Numerics are always REAL — whichever [`Engine`] the offload decision
//! selects (PJRT artifact for the GPU target, native Rust for the CPU
//! targets); only the *latency accounting* runs through the calibrated
//! device simulator (we do not own a Nexus 5). Every engine is pinned to
//! the same trained weights and golden-tested against the JAX oracle, so
//! the offload decision never changes the answer, only the cost —
//! exactly the paper's setting (DESIGN.md §3).
//!
//! Construction goes through [`RouterBuilder`]:
//!
//! ```text
//! let router = Router::builder()
//!     .policy(OffloadPolicy::CostModel)
//!     .device(device)
//!     .max_wait(Duration::from_millis(2))
//!     .manifest(&manifest, runtime)?   // standard engine set
//!     .build()?;
//! ```

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::{Manifest, ModelShape};
use crate::coordinator::batcher::BatchCollector;
use crate::coordinator::device::DeviceState;
use crate::coordinator::engine::{
    CpuMultiEngine, CpuSingleEngine, Engine, EngineRegistry, PjrtEngine,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{target_label, DecisionCache, LoadSnapshot, OffloadPolicy};
use crate::har::CLASS_NAMES;
use crate::lstm::{LstmModel, WeightFile};
use crate::runtime::Runtime;
use crate::simulator::{simulate_inference, DeviceProfile, Target};
use crate::tensor::Tensor;

/// Per-request options for [`Router::submit_with`] / [`Router::classify_with`].
#[derive(Debug, Clone, Default)]
pub struct ClassifyOptions {
    /// Caller-chosen request id, echoed in the reply (and on the wire).
    pub id: Option<u64>,
    /// Pin this request to a target, bypassing the offload policy. The
    /// override applies to the whole dispatched batch (mixed batches use
    /// the earliest override); if no engine serves it, the registry's
    /// failover order decides.
    pub target: Option<Target>,
    /// Upper bound on how long the caller waits for the reply in
    /// [`Router::classify_with`]; exceeding it yields
    /// [`ServeError::DeadlineExceeded`].
    pub deadline: Option<Duration>,
}

/// One classify request.
pub struct ServeRequest {
    /// Flat `[seq_len * input_dim]` window.
    pub window: Vec<f32>,
    pub opts: ClassifyOptions,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<ServeReply, ServeError>>,
}

/// The answer sent back to the client.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// Echo of [`ClassifyOptions::id`].
    pub id: Option<u64>,
    pub class: usize,
    pub label: String,
    pub logits: Vec<f32>,
    /// Wall-clock latency on this host (enqueue → reply), ns.
    pub wall_ns: u64,
    /// Simulated on-device latency (the paper's metric), ns.
    pub sim_ns: u64,
    pub target: &'static str,
    pub batch_size: usize,
}

/// Serving-side failure delivered on the reply channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Every registered engine failed for this batch.
    EngineFailure(String),
    /// The caller's [`ClassifyOptions::deadline`] elapsed first.
    DeadlineExceeded,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::EngineFailure(msg) => write!(f, "engine failure: {msg}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Handle to the router thread.
#[derive(Clone)]
pub struct Router {
    tx: mpsc::Sender<ServeRequest>,
    pub metrics: Arc<Metrics>,
    pub device: DeviceState,
    shape: ModelShape,
    joiner: Arc<Joiner>,
}

struct Joiner {
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Router {
    /// Start building a router. See [`RouterBuilder`].
    pub fn builder() -> RouterBuilder {
        RouterBuilder::new()
    }

    /// Submit a window; returns the reply receiver.
    pub fn submit(
        &self,
        window: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<ServeReply, ServeError>>> {
        self.submit_with(window, ClassifyOptions::default())
    }

    /// Submit a window with per-request options.
    pub fn submit_with(
        &self,
        window: Vec<f32>,
        opts: ClassifyOptions,
    ) -> Result<mpsc::Receiver<Result<ServeReply, ServeError>>> {
        let expect = self.window_len();
        if window.len() != expect {
            return Err(anyhow!("window has {} values, expected {expect}", window.len()));
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(ServeRequest { window, opts, enqueued: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("router gone"))?;
        Ok(rrx)
    }

    /// Blocking classify (submit + wait).
    pub fn classify(&self, window: Vec<f32>) -> Result<ServeReply> {
        self.classify_with(window, ClassifyOptions::default())
    }

    /// Blocking classify with per-request options (id echo, target
    /// override, deadline).
    pub fn classify_with(&self, window: Vec<f32>, opts: ClassifyOptions) -> Result<ServeReply> {
        let deadline = opts.deadline;
        let rrx = self.submit_with(window, opts)?;
        let outcome = match deadline {
            Some(limit) => rrx
                .recv_timeout(limit)
                .map_err(|_| anyhow::Error::new(ServeError::DeadlineExceeded))?,
            None => rrx.recv().context("router dropped reply")?,
        };
        outcome.map_err(anyhow::Error::new)
    }

    pub fn shape(&self) -> ModelShape {
        self.shape
    }

    /// Flat window length (`seq_len * input_dim`) this router accepts.
    pub fn window_len(&self) -> usize {
        self.shape.seq_len * self.shape.input_dim
    }
}

impl Drop for Joiner {
    fn drop(&mut self) {
        // Router thread exits when the last sender drops; just join.
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Fluent constructor for [`Router`] — the only way to build one.
///
/// Defaults: paper-default [`ModelShape`], cost-model policy, 2 ms
/// batching deadline, 4 CPU threads, a fresh simulated Nexus 5. At least
/// one engine is required: either the standard set via
/// [`RouterBuilder::manifest`] or custom ones via [`RouterBuilder::engine`].
pub struct RouterBuilder {
    shape: ModelShape,
    policy: OffloadPolicy,
    max_wait: Duration,
    cpu_threads: usize,
    device: Option<DeviceState>,
    registry: EngineRegistry,
}

impl Default for RouterBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl RouterBuilder {
    pub fn new() -> Self {
        Self {
            shape: ModelShape::default(),
            policy: OffloadPolicy::CostModel,
            max_wait: Duration::from_millis(2),
            cpu_threads: 4,
            device: None,
            registry: EngineRegistry::new(),
        }
    }

    /// Model shape served by this router (set BEFORE `.manifest(..)`).
    pub fn shape(mut self, shape: ModelShape) -> Self {
        self.shape = shape;
        self
    }

    /// Offload policy (default: cost model).
    pub fn policy(mut self, policy: OffloadPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Simulated device state shared with callers (default: idle Nexus 5).
    pub fn device(mut self, device: DeviceState) -> Self {
        self.device = Some(device);
        self
    }

    /// Batching deadline: how long the oldest request may wait.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Threads for the native multi-thread CPU engine (set BEFORE
    /// `.manifest(..)`).
    pub fn cpu_threads(mut self, threads: usize) -> Self {
        self.cpu_threads = threads.max(1);
        self
    }

    /// Register a custom engine; replaces any registered engine of the
    /// same target kind. Registration order is failover order.
    pub fn engine(mut self, engine: Box<dyn Engine>) -> Self {
        self.registry.register(engine);
        self
    }

    /// Register the standard engine set from the AOT artifacts: the PJRT
    /// GPU engine plus native single- and multi-thread CPU engines, all
    /// sharing the artifact weights.
    pub fn manifest(mut self, manifest: &Manifest, runtime: Runtime) -> Result<Self> {
        let shape = self.shape;
        let batches = manifest.batches_for(shape);
        if batches.is_empty() {
            return Err(anyhow!(
                "no compiled variants for shape {shape:?}; run `make artifacts`"
            ));
        }
        let weights_file = manifest
            .variant_for(shape, batches[0])
            .context("variant for smallest batch")?
            .weights
            .clone();
        let wf = WeightFile::load(manifest.path(&weights_file))?;
        let native = Arc::new(LstmModel::from_weight_file(shape, &wf)?);
        let threads = self.cpu_threads;
        self.registry.register(Box::new(PjrtEngine::new(manifest, runtime, shape)?));
        self.registry.register(Box::new(CpuMultiEngine::new(Arc::clone(&native), threads)));
        self.registry.register(Box::new(CpuSingleEngine::new(native)));
        Ok(self)
    }

    /// Spawn the router thread.
    pub fn build(self) -> Result<Router> {
        if self.registry.is_empty() {
            return Err(anyhow!(
                "router needs at least one engine: call .manifest(..) or .engine(..)"
            ));
        }
        let device =
            self.device.unwrap_or_else(|| DeviceState::new(DeviceProfile::nexus5()));
        // Batch sizes the collector may form: the union of what the
        // engines can execute. Engines that accept any batch contribute
        // nothing; if only such engines are registered, use a dyadic
        // ladder so burst traffic still batches.
        let mut batches: Vec<usize> = self
            .registry
            .iter()
            .flat_map(|e| e.supported_batches().iter().copied())
            .collect();
        if batches.is_empty() {
            batches = vec![1, 2, 4, 8];
        }
        batches.sort_unstable();
        batches.dedup();

        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<ServeRequest>();
        let worker = Worker {
            rx,
            collector: BatchCollector::new(batches, self.max_wait),
            queue: VecDeque::new(),
            engines: self.registry,
            device: device.clone(),
            metrics: Arc::clone(&metrics),
            shape: self.shape,
            policy: self.policy,
            max_wait: self.max_wait,
            decisions: DecisionCache::new(),
        };
        let handle = std::thread::Builder::new()
            .name("mobirnn-router".into())
            .spawn(move || worker.run())
            .context("spawning router")?;
        Ok(Router {
            tx,
            metrics,
            device,
            shape: self.shape,
            joiner: Arc::new(Joiner { handle: Mutex::new(Some(handle)) }),
        })
    }
}

struct Worker {
    rx: mpsc::Receiver<ServeRequest>,
    collector: BatchCollector,
    queue: VecDeque<ServeRequest>,
    engines: EngineRegistry,
    device: DeviceState,
    metrics: Arc<Metrics>,
    shape: ModelShape,
    policy: OffloadPolicy,
    max_wait: Duration,
    decisions: DecisionCache,
}

impl Worker {
    fn run(mut self) {
        let mut last_tick = Instant::now();
        loop {
            // Virtual device time advances with real time (queue drain).
            let now = Instant::now();
            self.device.advance_virtual(now.duration_since(last_tick).as_nanos() as u64);
            last_tick = now;

            // Wait for work or the batching deadline.
            let timeout = self
                .collector
                .time_to_deadline(now)
                .unwrap_or(Duration::from_millis(50));
            match self.rx.recv_timeout(timeout) {
                Ok(req) => {
                    self.collector.push(req.enqueued);
                    self.queue.push_back(req);
                    // Opportunistically drain whatever is already queued.
                    while let Ok(req) = self.rx.try_recv() {
                        self.collector.push(req.enqueued);
                        self.queue.push_back(req);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Serve the tail (poll "in the future" so every
                    // deadline fires), then exit.
                    while self.collector.pending() > 0 {
                        self.dispatch_once(Instant::now() + 2 * self.max_wait);
                    }
                    return;
                }
            }
            self.dispatch_once(Instant::now());
        }
    }

    fn dispatch_once(&mut self, now: Instant) {
        let Some(plan) = self.collector.poll(now) else { return };

        let reqs: Vec<ServeRequest> =
            (0..plan.take).filter_map(|_| self.queue.pop_front()).collect();
        if reqs.is_empty() {
            return;
        }
        let shape = self.shape;
        let window_len = shape.seq_len * shape.input_dim;

        // Build the padded [B, T, D] tensor.
        let mut data = Vec::with_capacity(plan.padded_to * window_len);
        for r in &reqs {
            data.extend_from_slice(&r.window);
        }
        data.resize(plan.padded_to * window_len, 0.0);
        let x = Tensor::new(vec![plan.padded_to, shape.seq_len, shape.input_dim], data);

        // Offload decision: an explicit per-request override wins;
        // otherwise the policy decides on current load.
        let target = match reqs.iter().find_map(|r| r.opts.target) {
            Some(t) => t,
            None => {
                let load = LoadSnapshot {
                    gpu_util: self.device.effective_gpu_util(),
                    cpu_util: self.device.cpu_util(),
                };
                self.decisions.decide(
                    &self.policy,
                    self.device.profile(),
                    shape,
                    plan.padded_to,
                    load,
                )
            }
        };

        // REAL numerics through the engine registry; generic failover.
        // `errors` counts engine execution failures (same unit on the
        // partial-failover and total-failure paths).
        let t0 = Instant::now();
        let (outcome, engine_errors) = self.engines.infer_with_failover(target, &x);
        self.metrics.errors.fetch_add(engine_errors, Ordering::Relaxed);
        let (logits, target) = match outcome {
            Ok((logits, used)) => (logits, used),
            Err(e) => {
                let msg = format!("{e:#}");
                for req in reqs {
                    let _ = req.reply.send(Err(ServeError::EngineFailure(msg.clone())));
                }
                return;
            }
        };
        let compute_ns = t0.elapsed().as_nanos() as u64;

        // SIMULATED device latency. The paper's measurement is CLOSED-LOOP
        // (inferences run back-to-back on the phone), so each batch's
        // device time elapses on the virtual clock before the next
        // dispatch: enqueue + advance drains the queue exactly, keeping
        // sim_ns = work_ns for sequential batches while still charging
        // queueing delay if dispatches ever overlap.
        let util = match target {
            Target::Gpu(_) => self.device.gpu_util(),
            _ => self.device.cpu_util(),
        };
        let work_ns =
            simulate_inference(self.device.profile(), shape, plan.padded_to, target, util);
        let sim_ns = match target {
            Target::Gpu(_) => {
                let latency = self.device.enqueue_gpu(work_ns);
                self.device.advance_virtual(work_ns);
                latency
            }
            _ => work_ns,
        };

        // Account + reply.
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.metrics.padded_slots.fetch_add(plan.padding() as u64, Ordering::Relaxed);
        self.metrics.compute_latency.record(compute_ns);
        self.metrics.sim_latency.record(sim_ns);
        match target {
            Target::Gpu(_) => self.metrics.gpu_dispatches.fetch_add(1, Ordering::Relaxed),
            _ => self.metrics.cpu_dispatches.fetch_add(1, Ordering::Relaxed),
        };
        let done = Instant::now();
        for (i, req) in reqs.into_iter().enumerate() {
            let wall_ns = done.duration_since(req.enqueued).as_nanos() as u64;
            self.metrics.wall_latency.record(wall_ns);
            let row = logits.row(i).to_vec();
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = req.reply.send(Ok(ServeReply {
                id: req.opts.id,
                class,
                label: CLASS_NAMES.get(class).unwrap_or(&"?").to_string(),
                logits: row,
                wall_ns,
                sim_ns,
                target: target_label(target),
                batch_size: plan.padded_to,
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::testutil::FixedEngine;
    use crate::har;
    use crate::simulator::Factorization;

    fn setup(policy: OffloadPolicy) -> Option<(Router, Manifest)> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let man = Manifest::load(dir).unwrap();
        let rt = Runtime::start(&man).unwrap();
        let router = Router::builder()
            .policy(policy)
            .max_wait(Duration::from_millis(1))
            .manifest(&man, rt)
            .unwrap()
            .build()
            .unwrap();
        Some((router, man))
    }

    /// A router over a single fake engine — exercises the builder and the
    /// serving loop without artifacts.
    fn fixed_router(policy: OffloadPolicy, engines: Vec<FixedEngine>) -> Router {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let mut b = Router::builder()
            .shape(shape)
            .policy(policy)
            .max_wait(Duration::from_millis(1));
        for e in engines {
            b = b.engine(Box::new(e));
        }
        b.build().unwrap()
    }

    #[test]
    fn builder_requires_an_engine() {
        let err = Router::builder().build().unwrap_err().to_string();
        assert!(err.contains("engine"), "{err}");
    }

    #[test]
    fn fixed_engine_round_trip_without_artifacts() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.class, 1, "FixedEngine always predicts class 1");
        // Policy may ask for the GPU; the registry fails over to the only
        // engine present without counting an error.
        assert_eq!(reply.target, "cpu");
        assert!(reply.sim_ns > 0);
        assert_eq!(router.metrics.errors.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn engine_failover_is_generic() {
        let router = fixed_router(
            OffloadPolicy::Static(Target::Gpu(Factorization::Coarse)),
            vec![
                FixedEngine::failing(Target::Gpu(Factorization::Coarse)),
                FixedEngine::new(Target::CpuMulti(4)),
            ],
        );
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.target, "cpu-multi", "failover must reach the next engine");
        assert_eq!(router.metrics.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn all_engines_failing_surfaces_serve_error() {
        let router = fixed_router(
            OffloadPolicy::Static(Target::CpuSingle),
            vec![FixedEngine::failing(Target::CpuSingle)],
        );
        let outcome = router.submit(vec![0.0; 30]).unwrap().recv().unwrap();
        match outcome {
            Err(ServeError::EngineFailure(msg)) => assert!(msg.contains("failed"), "{msg}"),
            other => panic!("expected EngineFailure, got {other:?}"),
        }
        assert!(router.metrics.errors.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn options_carry_id_and_deadline() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        let reply = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions { id: Some(99), ..Default::default() },
            )
            .unwrap();
        assert_eq!(reply.id, Some(99));

        // A zero deadline elapses before the 1 ms batching wait.
        let err = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions { deadline: Some(Duration::ZERO), ..Default::default() },
            )
            .unwrap_err();
        assert!(
            err.downcast_ref::<ServeError>() == Some(&ServeError::DeadlineExceeded),
            "{err:#}"
        );
    }

    #[test]
    fn classify_roundtrip_gpu() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(4, 11);
        for i in 0..4 {
            let reply = router.classify(ds.window(i).to_vec()).unwrap();
            assert!(reply.class < har::NUM_CLASSES);
            assert_eq!(reply.logits.len(), har::NUM_CLASSES);
            assert_eq!(reply.target, "gpu", "idle device should offload");
            assert!(reply.sim_ns > 0);
        }
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn classify_cpu_matches_gpu_numerics() {
        // The offload decision must not change answers: native CPU logits
        // track the XLA logits within fp tolerance.
        let Some((gpu_router, man)) = setup(OffloadPolicy::Static(Target::Gpu(
            Factorization::Coarse,
        ))) else {
            return;
        };
        let rt = Runtime::start(&man).unwrap();
        let cpu_router = Router::builder()
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(1))
            .manifest(&man, rt)
            .unwrap()
            .build()
            .unwrap();
        let ds = har::generate(6, 13);
        for i in 0..6 {
            let g = gpu_router.classify(ds.window(i).to_vec()).unwrap();
            let c = cpu_router.classify(ds.window(i).to_vec()).unwrap();
            assert_eq!(g.target, "gpu");
            assert_eq!(c.target, "cpu");
            assert_eq!(g.class, c.class, "window {i}: targets disagree");
            for (a, b) in g.logits.iter().zip(&c.logits) {
                assert!((a - b).abs() < 1e-3, "logit drift {a} vs {b}");
            }
        }
    }

    #[test]
    fn per_request_target_override_beats_policy() {
        // Idle device: the cost model would pick the GPU, but the
        // override pins this request to the single-thread CPU engine.
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(2, 15);
        let forced = router
            .classify_with(
                ds.window(0).to_vec(),
                ClassifyOptions { target: Some(Target::CpuSingle), ..Default::default() },
            )
            .unwrap();
        assert_eq!(forced.target, "cpu", "override must bypass the policy");
        let free = router.classify(ds.window(1).to_vec()).unwrap();
        assert_eq!(free.target, "gpu", "non-overridden requests still follow the policy");
    }

    #[test]
    fn high_load_switches_to_cpu() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        router.device.set_gpu_util(0.9);
        router.device.set_cpu_util(0.9);
        let ds = har::generate(1, 17);
        let reply = router.classify(ds.window(0).to_vec()).unwrap();
        assert_ne!(reply.target, "gpu", "§4.5: loaded GPU must not be chosen");
    }

    #[test]
    fn submit_rejects_wrong_window() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        assert!(router.submit(vec![0.0; 7]).is_err());
    }

    #[test]
    fn batches_form_under_burst() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(16, 19);
        let rxs: Vec<_> =
            (0..16).map(|i| router.submit(ds.window(i).to_vec()).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.batch_size >= 1);
        }
        let batches = router.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 16, "burst should batch: {batches} batches for 16 reqs");
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 16);
    }
}
