//! The serving loop: queue → batch → offload decision → execute → reply.
//!
//! Numerics are always REAL — the PJRT artifact (GPU target) or the
//! native Rust engine (CPU targets); only the *latency accounting* runs
//! through the calibrated device simulator (we do not own a Nexus 5).
//! Both numeric paths are pinned to the same trained weights and
//! golden-tested against the JAX oracle, so the offload decision never
//! changes the answer, only the cost — exactly the paper's setting.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::{Manifest, ModelShape};
use crate::coordinator::batcher::BatchCollector;
use crate::coordinator::device::DeviceState;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{target_label, DecisionCache, LoadSnapshot, OffloadPolicy};
use crate::har::CLASS_NAMES;
use crate::lstm::{LstmModel, ThreadedLstm};
use crate::runtime::Runtime;
use crate::simulator::{simulate_inference, Target};
use crate::tensor::Tensor;

/// One classify request.
pub struct ServeRequest {
    /// Flat `[seq_len * input_dim]` window.
    pub window: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<ServeReply>,
}

/// The answer sent back to the client.
#[derive(Debug, Clone)]
pub struct ServeReply {
    pub class: usize,
    pub label: String,
    pub logits: Vec<f32>,
    /// Wall-clock latency on this host (enqueue → reply), ns.
    pub wall_ns: u64,
    /// Simulated on-device latency (the paper's metric), ns.
    pub sim_ns: u64,
    pub target: &'static str,
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub shape: ModelShape,
    pub policy: OffloadPolicy,
    /// Batching deadline: how long the oldest request may wait.
    pub max_wait: Duration,
    /// Threads for the native multi-thread CPU path.
    pub cpu_threads: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            shape: ModelShape::default(),
            policy: OffloadPolicy::CostModel,
            max_wait: Duration::from_millis(2),
            cpu_threads: 4,
        }
    }
}

/// Handle to the router thread.
#[derive(Clone)]
pub struct Router {
    tx: mpsc::Sender<ServeRequest>,
    pub metrics: Arc<Metrics>,
    pub device: DeviceState,
    cfg: RouterConfig,
    joiner: Arc<Joiner>,
}

struct Joiner {
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Router {
    /// Start the router over a PJRT runtime + native engine.
    pub fn start(
        manifest: &Manifest,
        runtime: Runtime,
        device: DeviceState,
        cfg: RouterConfig,
    ) -> Result<Self> {
        let batches = manifest.batches_for(cfg.shape);
        if batches.is_empty() {
            return Err(anyhow!(
                "no compiled variants for shape {:?}; run `make artifacts`",
                cfg.shape
            ));
        }
        // Native engine shares the artifact weights with the PJRT path.
        let weights_file = manifest
            .variant_for(cfg.shape, batches[0])
            .context("variant for smallest batch")?
            .weights
            .clone();
        let wf = crate::lstm::WeightFile::load(manifest.path(&weights_file))?;
        let native = Arc::new(LstmModel::from_weight_file(cfg.shape, &wf)?);
        let pool = ThreadedLstm::new(Arc::clone(&native), cfg.cpu_threads);

        // Pre-compile every batch variant so serving never hits XLA compile.
        for &b in &batches {
            let name = cfg.shape.variant_name(b);
            runtime.preload(&name)?;
        }

        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<ServeRequest>();
        let worker = Worker {
            rx,
            collector: BatchCollector::new(batches, cfg.max_wait),
            queue: VecDeque::new(),
            runtime,
            native,
            pool,
            device: device.clone(),
            metrics: Arc::clone(&metrics),
            cfg: cfg.clone(),
            decisions: DecisionCache::new(),
        };
        let handle = std::thread::Builder::new()
            .name("mobirnn-router".into())
            .spawn(move || worker.run())
            .context("spawning router")?;
        Ok(Self {
            tx,
            metrics,
            device,
            cfg,
            joiner: Arc::new(Joiner { handle: Mutex::new(Some(handle)) }),
        })
    }

    /// Submit a window; returns the reply receiver.
    pub fn submit(&self, window: Vec<f32>) -> Result<mpsc::Receiver<ServeReply>> {
        let expect = self.cfg.shape.seq_len * self.cfg.shape.input_dim;
        if window.len() != expect {
            return Err(anyhow!("window has {} values, expected {expect}", window.len()));
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(ServeRequest { window, enqueued: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("router gone"))?;
        Ok(rrx)
    }

    /// Blocking classify (submit + wait).
    pub fn classify(&self, window: Vec<f32>) -> Result<ServeReply> {
        self.submit(window)?.recv().context("router dropped reply")
    }

    pub fn shape(&self) -> ModelShape {
        self.cfg.shape
    }
}

impl Drop for Joiner {
    fn drop(&mut self) {
        // Router thread exits when the last sender drops; just join.
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

struct Worker {
    rx: mpsc::Receiver<ServeRequest>,
    collector: BatchCollector,
    queue: VecDeque<ServeRequest>,
    runtime: Runtime,
    native: Arc<LstmModel>,
    pool: ThreadedLstm,
    device: DeviceState,
    metrics: Arc<Metrics>,
    cfg: RouterConfig,
    decisions: DecisionCache,
}

impl Worker {
    fn run(mut self) {
        let mut last_tick = Instant::now();
        loop {
            // Virtual device time advances with real time (queue drain).
            let now = Instant::now();
            self.device.advance_virtual(now.duration_since(last_tick).as_nanos() as u64);
            last_tick = now;

            // Wait for work or the batching deadline.
            let timeout = self
                .collector
                .time_to_deadline(now)
                .unwrap_or(Duration::from_millis(50));
            match self.rx.recv_timeout(timeout) {
                Ok(req) => {
                    self.collector.push(req.enqueued);
                    self.queue.push_back(req);
                    // Opportunistically drain whatever is already queued.
                    while let Ok(req) = self.rx.try_recv() {
                        self.collector.push(req.enqueued);
                        self.queue.push_back(req);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Serve the tail (poll "in the future" so every
                    // deadline fires), then exit.
                    while self.collector.pending() > 0 {
                        self.dispatch_once(Instant::now() + 2 * self.cfg.max_wait);
                    }
                    return;
                }
            }
            self.dispatch_once(Instant::now());
        }
    }

    fn dispatch_once(&mut self, now: Instant) {
        let Some(plan) = self.collector.poll(now) else { return };

        let reqs: Vec<ServeRequest> =
            (0..plan.take).filter_map(|_| self.queue.pop_front()).collect();
        if reqs.is_empty() {
            return;
        }
        let shape = self.cfg.shape;
        let window_len = shape.seq_len * shape.input_dim;

        // Build the padded [B, T, D] tensor.
        let mut data = Vec::with_capacity(plan.padded_to * window_len);
        for r in &reqs {
            data.extend_from_slice(&r.window);
        }
        data.resize(plan.padded_to * window_len, 0.0);
        let x = Tensor::new(vec![plan.padded_to, shape.seq_len, shape.input_dim], data);

        // Offload decision on current load.
        let load = LoadSnapshot {
            gpu_util: self.device.effective_gpu_util(),
            cpu_util: self.device.cpu_util(),
        };
        let target = self.decisions.decide(
            &self.cfg.policy,
            self.device.profile(),
            shape,
            plan.padded_to,
            load,
        );

        // REAL numerics.
        let t0 = Instant::now();
        let logits = match target {
            Target::Gpu(_) => {
                let variant = shape.variant_name(plan.padded_to);
                match self.runtime.execute(&variant, x.clone()) {
                    Ok(l) => l,
                    Err(e) => {
                        eprintln!("[router] PJRT error, falling back to native: {e:#}");
                        self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let mut st = crate::lstm::model::InferenceState::new(shape);
                        self.native.forward_batch(&x, &mut st)
                    }
                }
            }
            Target::CpuMulti(_) => self.pool.forward_batch(&x),
            Target::CpuSingle => {
                let mut st = crate::lstm::model::InferenceState::new(shape);
                self.native.forward_batch(&x, &mut st)
            }
        };
        let compute_ns = t0.elapsed().as_nanos() as u64;

        // SIMULATED device latency. The paper's measurement is CLOSED-LOOP
        // (inferences run back-to-back on the phone), so each batch's
        // device time elapses on the virtual clock before the next
        // dispatch: enqueue + advance drains the queue exactly, keeping
        // sim_ns = work_ns for sequential batches while still charging
        // queueing delay if dispatches ever overlap.
        let util = match target {
            Target::Gpu(_) => self.device.gpu_util(),
            _ => self.device.cpu_util(),
        };
        let work_ns =
            simulate_inference(self.device.profile(), shape, plan.padded_to, target, util);
        let sim_ns = match target {
            Target::Gpu(_) => {
                let latency = self.device.enqueue_gpu(work_ns);
                self.device.advance_virtual(work_ns);
                latency
            }
            _ => work_ns,
        };

        // Account + reply.
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        self.metrics.padded_slots.fetch_add(plan.padding() as u64, Ordering::Relaxed);
        self.metrics.compute_latency.record(compute_ns);
        self.metrics.sim_latency.record(sim_ns);
        match target {
            Target::Gpu(_) => self.metrics.gpu_dispatches.fetch_add(1, Ordering::Relaxed),
            _ => self.metrics.cpu_dispatches.fetch_add(1, Ordering::Relaxed),
        };
        let done = Instant::now();
        for (i, req) in reqs.into_iter().enumerate() {
            let wall_ns = done.duration_since(req.enqueued).as_nanos() as u64;
            self.metrics.wall_latency.record(wall_ns);
            let row = logits.row(i).to_vec();
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0);
            let _ = req.reply.send(ServeReply {
                class,
                label: CLASS_NAMES.get(class).unwrap_or(&"?").to_string(),
                logits: row,
                wall_ns,
                sim_ns,
                target: target_label(target),
                batch_size: plan.padded_to,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::har;
    use crate::simulator::DeviceProfile;

    fn setup(policy: OffloadPolicy) -> Option<(Router, Manifest)> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let man = Manifest::load(dir).unwrap();
        let rt = Runtime::start(&man).unwrap();
        let device = DeviceState::new(DeviceProfile::nexus5());
        let router = Router::start(
            &man,
            rt,
            device,
            RouterConfig { policy, max_wait: Duration::from_millis(1), ..Default::default() },
        )
        .unwrap();
        Some((router, man))
    }

    #[test]
    fn classify_roundtrip_gpu() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(4, 11);
        for i in 0..4 {
            let reply = router.classify(ds.window(i).to_vec()).unwrap();
            assert!(reply.class < har::NUM_CLASSES);
            assert_eq!(reply.logits.len(), har::NUM_CLASSES);
            assert_eq!(reply.target, "gpu", "idle device should offload");
            assert!(reply.sim_ns > 0);
        }
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn classify_cpu_matches_gpu_numerics() {
        // The offload decision must not change answers: native CPU logits
        // track the XLA logits within fp tolerance.
        let Some((gpu_router, man)) = setup(OffloadPolicy::Static(Target::Gpu(
            crate::simulator::Factorization::Coarse,
        ))) else {
            return;
        };
        let rt = Runtime::start(&man).unwrap();
        let cpu_router = Router::start(
            &man,
            rt,
            DeviceState::new(DeviceProfile::nexus5()),
            RouterConfig {
                policy: OffloadPolicy::Static(Target::CpuSingle),
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let ds = har::generate(6, 13);
        for i in 0..6 {
            let g = gpu_router.classify(ds.window(i).to_vec()).unwrap();
            let c = cpu_router.classify(ds.window(i).to_vec()).unwrap();
            assert_eq!(g.target, "gpu");
            assert_eq!(c.target, "cpu");
            assert_eq!(g.class, c.class, "window {i}: targets disagree");
            for (a, b) in g.logits.iter().zip(&c.logits) {
                assert!((a - b).abs() < 1e-3, "logit drift {a} vs {b}");
            }
        }
    }

    #[test]
    fn high_load_switches_to_cpu() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        router.device.set_gpu_util(0.9);
        router.device.set_cpu_util(0.9);
        let ds = har::generate(1, 17);
        let reply = router.classify(ds.window(0).to_vec()).unwrap();
        assert_ne!(reply.target, "gpu", "§4.5: loaded GPU must not be chosen");
    }

    #[test]
    fn submit_rejects_wrong_window() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        assert!(router.submit(vec![0.0; 7]).is_err());
    }

    #[test]
    fn batches_form_under_burst() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(16, 19);
        let rxs: Vec<_> =
            (0..16).map(|i| router.submit(ds.window(i).to_vec()).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.batch_size >= 1);
        }
        let batches = router.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 16, "burst should batch: {batches} batches for 16 reqs");
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 16);
    }
}
