//! The serving SCHEDULER: admit → batch → offload decision → dispatch.
//!
//! Since the pipelined-dispatch refactor (DESIGN.md §9) the router
//! thread no longer executes anything: it is a pure scheduler that
//! admits requests against a bounded queue ([`RouterBuilder::max_queue`];
//! overflow is shed immediately as [`ServeError::Overloaded`]), forms
//! batches, decides targets — steering away from pools that are already
//! deep in flight, the paper's §4.5 behavior driven by real serving
//! state — and hands each batch to the per-engine worker pools in
//! `coordinator/engine.rs`. Execution, latency simulation and the
//! replies happen on the pool workers, so a GPU-target batch and a
//! CPU-target batch run CONCURRENTLY instead of head-of-line-blocking
//! each other.
//!
//! Numerics are always REAL — whichever [`Engine`] the offload decision
//! selects (PJRT artifact for the GPU target, native Rust for the CPU
//! targets); only the *latency accounting* runs through the calibrated
//! device simulator (we do not own a Nexus 5). Every engine is pinned to
//! the same trained weights and golden-tested against the JAX oracle, so
//! the offload decision never changes the answer, only the cost —
//! exactly the paper's setting (DESIGN.md §3).
//!
//! Construction goes through [`RouterBuilder`]:
//!
//! ```text
//! let router = Router::builder()
//!     .policy(OffloadPolicy::CostModel)
//!     .device(device)
//!     .max_wait(Duration::from_millis(2))
//!     .max_queue(256)                  // admission bound (default)
//!     .manifest(&manifest, runtime)?   // standard engine set
//!     .build()?;
//! ```

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::{Manifest, ModelShape};
use crate::coordinator::batcher::{plan_batch, BatchCollector};
use crate::coordinator::device::DeviceState;
use crate::coordinator::engine::{
    BatchJob, CpuMultiEngine, CpuQuantEngine, CpuSingleEngine, Engine, EnginePools,
    EngineRegistry, PjrtEngine, StreamJob,
};
use crate::coordinator::health::HealthRegistry;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{DecisionCache, LoadSnapshot, OffloadPolicy, Precision};
use crate::lstm::{LstmModel, WeightFile};
use crate::runtime::Runtime;
use crate::session::{SessionError, SessionStore};
use crate::simulator::{simulate_inference, DeviceProfile, Target};
use crate::tensor::Tensor;

/// How long the scheduler backs off when every engine pool's queue is
/// full before retrying the blocked batch.
const POOL_FULL_BACKOFF: Duration = Duration::from_micros(200);

/// Per-request options for [`Router::submit_with`] / [`Router::classify_with`].
#[derive(Debug, Clone, Default)]
pub struct ClassifyOptions {
    /// Caller-chosen request id, echoed in the reply (and on the wire).
    pub id: Option<u64>,
    /// Pin this request to a target, bypassing the offload policy. The
    /// override applies to the whole dispatched batch (mixed batches use
    /// the earliest override); if no engine serves it, the registry's
    /// failover order decides.
    pub target: Option<Target>,
    /// Numeric precision for this request (DESIGN.md §10): `Int8` routes
    /// to the quantized engine, `F32` (or absent) stays on the exact
    /// engines the policy ranks. Unlike `target` (where every engine
    /// computes the same answers), precision changes numerics, so the
    /// scheduler never mixes classes in one batch — an int8 request
    /// batches only with other int8 requests. An explicit `target`
    /// override beats `precision`.
    pub precision: Option<Precision>,
    /// Upper bound on how long the caller waits for the reply in
    /// [`Router::classify_with`]; exceeding it yields
    /// [`ServeError::DeadlineExceeded`]. The deadline also bounds the
    /// retry budget failover spends on the batch (DESIGN.md §15).
    pub deadline: Option<Duration>,
    /// Opt in to brownout degradation: when every f32 pool's breaker is
    /// open, the scheduler may serve this request on the int8 tier
    /// instead of shedding it, marking the reply `degraded:"int8"`
    /// (DESIGN.md §15). Never applies to requests with an explicit
    /// `target` override or int8 precision.
    pub allow_degraded: bool,
}

/// Where a finished request's outcome goes. The blocking API wraps an
/// `mpsc` channel ([`ReplySink::channel`]); the event-driven server
/// (DESIGN.md §12) registers a one-shot callback instead
/// ([`ReplySink::callback`]) so no thread parks waiting for a reply —
/// whichever pool worker resolves the request runs the callback, which
/// pushes the response onto the owning I/O loop's completion queue and
/// wakes it.
pub enum ReplySink<T> {
    /// Deliver into a channel; the submitting thread holds the receiver.
    Channel(mpsc::Sender<Result<T, ServeError>>),
    /// Run a one-shot closure on whichever thread resolves the request.
    Callback(Mutex<Option<Box<dyn FnOnce(Result<T, ServeError>) + Send>>>),
}

impl<T> ReplySink<T> {
    pub fn channel(tx: mpsc::Sender<Result<T, ServeError>>) -> Self {
        ReplySink::Channel(tx)
    }

    pub fn callback(f: impl FnOnce(Result<T, ServeError>) + Send + 'static) -> Self {
        ReplySink::Callback(Mutex::new(Some(Box::new(f))))
    }

    /// Deliver the outcome. Returns `false` when nobody is listening:
    /// the channel receiver hung up, or the callback already fired (it
    /// runs at most once).
    pub fn send(&self, outcome: Result<T, ServeError>) -> bool {
        match self {
            ReplySink::Channel(tx) => tx.send(outcome).is_ok(),
            ReplySink::Callback(slot) => {
                let f = slot.lock().ok().and_then(|mut s| s.take());
                match f {
                    Some(f) => {
                        f(outcome);
                        true
                    }
                    None => false,
                }
            }
        }
    }
}

/// One classify request.
pub struct ServeRequest {
    /// Flat `[seq_len * input_dim]` window.
    pub window: Vec<f32>,
    pub opts: ClassifyOptions,
    pub enqueued: Instant,
    pub reply: ReplySink<ServeReply>,
}

/// The answer sent back to the client.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// Echo of [`ClassifyOptions::id`].
    pub id: Option<u64>,
    pub class: usize,
    pub label: String,
    pub logits: Vec<f32>,
    /// Wall-clock latency on this host (enqueue → reply), ns.
    pub wall_ns: u64,
    /// Simulated on-device latency (the paper's metric), ns.
    pub sim_ns: u64,
    pub target: &'static str,
    pub batch_size: usize,
    /// `Some("int8")` when brownout served this f32 request on the
    /// quant tier (the caller opted in via `allow_degraded`).
    pub degraded: Option<&'static str>,
}

/// Serving-side failure delivered on the reply channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Every registered engine failed for this batch.
    EngineFailure(String),
    /// The caller's [`ClassifyOptions::deadline`] elapsed first.
    DeadlineExceeded,
    /// Admission control rejected the request: the scheduler queue was
    /// already at [`RouterBuilder::max_queue`]. Shed immediately — a
    /// request that would only time out in the queue costs everyone
    /// else latency (the paper's §4.5 logic applied to overload).
    Overloaded,
    /// `classify_stream` named a session that does not exist (never
    /// opened, already closed, or evicted long enough ago that the
    /// eviction itself is no longer observable).
    SessionNotFound(u64),
    /// The session existed but its TTL lapsed; this lookup evicted it.
    SessionExpired(u64),
    /// Failover retries consumed the request's whole deadline budget
    /// without any pool accepting the batch (DESIGN.md §15). Typed so
    /// callers can tell "engines are broken" from "engines were too
    /// busy/broken for too long" — and so exhaustion is never a hang.
    RetriesExhausted,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::EngineFailure(msg) => write!(f, "engine failure: {msg}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServeError::Overloaded => write!(f, "overloaded: scheduler queue full"),
            ServeError::SessionNotFound(id) => write!(f, "session {id} not found"),
            ServeError::SessionExpired(id) => write!(f, "session {id} expired"),
            ServeError::RetriesExhausted => {
                write!(f, "retries exhausted: deadline budget consumed across failover")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// One streaming chunk bound for a pinned session (the stream analogue
/// of [`ServeRequest`]).
pub struct StreamRequest {
    pub session: u64,
    /// Flat `[steps, input_dim]` frames.
    pub frames: Vec<f32>,
    pub steps: usize,
    /// Caller-chosen request id, echoed in the reply.
    pub id: Option<u64>,
    pub enqueued: Instant,
    pub reply: ReplySink<StreamReply>,
}

/// Per-step results for one stream chunk.
#[derive(Debug, Clone)]
pub struct StreamReply {
    pub id: Option<u64>,
    pub session: u64,
    pub steps: usize,
    /// Predicted class after each step (`steps` entries).
    pub classes: Vec<usize>,
    /// Flat `[steps, C]` per-step logits.
    pub logits: Vec<f32>,
    /// Wall-clock latency on this host (enqueue → reply), ns.
    pub wall_ns: u64,
    /// The engine pool that actually served the chunk.
    pub target: &'static str,
}

/// What [`Router::open_session`] hands back.
#[derive(Debug, Clone)]
pub struct SessionInfo {
    pub id: u64,
    /// Label of the engine pool the session is pinned to.
    pub target: &'static str,
    pub ttl: Duration,
}

/// A message on the scheduler's intake channel.
enum SchedMsg {
    Classify(ServeRequest),
    Stream(StreamRequest),
}

/// Handle to the router thread.
#[derive(Clone)]
pub struct Router {
    tx: mpsc::Sender<SchedMsg>,
    pub metrics: Arc<Metrics>,
    pub device: DeviceState,
    shape: ModelShape,
    sessions: Arc<SessionStore>,
    /// Registered stream-capable targets, registration order — the pool
    /// a fresh session pins to is decided here, at open.
    stream_targets: Arc<Vec<Target>>,
    joiner: Arc<Joiner>,
}

struct Joiner {
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Router {
    /// Start building a router. See [`RouterBuilder`].
    pub fn builder() -> RouterBuilder {
        RouterBuilder::new()
    }

    /// Submit a window; returns the reply receiver.
    pub fn submit(
        &self,
        window: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<ServeReply, ServeError>>> {
        self.submit_with(window, ClassifyOptions::default())
    }

    /// Submit a window with per-request options.
    pub fn submit_with(
        &self,
        window: Vec<f32>,
        opts: ClassifyOptions,
    ) -> Result<mpsc::Receiver<Result<ServeReply, ServeError>>> {
        let expect = self.window_len();
        if window.len() != expect {
            return Err(anyhow!("window has {} values, expected {expect}", window.len()));
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(SchedMsg::Classify(ServeRequest {
                window,
                opts,
                enqueued: Instant::now(),
                reply: ReplySink::channel(rtx),
            }))
            .map_err(|_| anyhow!("router gone"))?;
        Ok(rrx)
    }

    /// Blocking classify (submit + wait).
    pub fn classify(&self, window: Vec<f32>) -> Result<ServeReply> {
        self.classify_with(window, ClassifyOptions::default())
    }

    /// Blocking classify with per-request options (id echo, target
    /// override, deadline).
    pub fn classify_with(&self, window: Vec<f32>, opts: ClassifyOptions) -> Result<ServeReply> {
        let deadline = opts.deadline;
        let rrx = self.submit_with(window, opts)?;
        let outcome = match deadline {
            Some(limit) => rrx
                .recv_timeout(limit)
                .map_err(|_| anyhow::Error::new(ServeError::DeadlineExceeded))?,
            None => rrx.recv().context("router dropped reply")?,
        };
        outcome.map_err(anyhow::Error::new)
    }

    /// Submit a window with a caller-provided reply sink — the
    /// non-blocking analogue of [`Router::submit_with`], used by the
    /// event-driven server (DESIGN.md §12). Returns `Err` only for an
    /// invalid window; the sink is dropped unfired and the caller still
    /// owns the error response. Once validation passes, every outcome —
    /// including scheduler shutdown — is delivered through the sink.
    pub fn submit_sink(
        &self,
        window: Vec<f32>,
        opts: ClassifyOptions,
        reply: ReplySink<ServeReply>,
    ) -> Result<()> {
        let expect = self.window_len();
        if window.len() != expect {
            return Err(anyhow!("window has {} values, expected {expect}", window.len()));
        }
        let msg =
            SchedMsg::Classify(ServeRequest { window, opts, enqueued: Instant::now(), reply });
        if let Err(mpsc::SendError(msg)) = self.tx.send(msg) {
            if let SchedMsg::Classify(req) = msg {
                req.reply.send(Err(ServeError::EngineFailure("router gone".into())));
            }
        }
        Ok(())
    }

    /// Stream analogue of [`Router::submit_sink`].
    pub fn submit_stream_sink(
        &self,
        session: u64,
        frames: Vec<f32>,
        id: Option<u64>,
        reply: ReplySink<StreamReply>,
    ) -> Result<()> {
        let dim = self.shape.input_dim;
        if frames.is_empty() || frames.len() % dim != 0 {
            return Err(anyhow!(
                "stream chunk of {} values is not a positive multiple of input_dim {dim}",
                frames.len()
            ));
        }
        let steps = frames.len() / dim;
        let msg = SchedMsg::Stream(StreamRequest {
            session,
            frames,
            steps,
            id,
            enqueued: Instant::now(),
            reply,
        });
        if let Err(mpsc::SendError(msg)) = self.tx.send(msg) {
            if let SchedMsg::Stream(req) = msg {
                req.reply.send(Err(ServeError::EngineFailure("router gone".into())));
            }
        }
        Ok(())
    }

    pub fn shape(&self) -> ModelShape {
        self.shape
    }

    /// Flat window length (`seq_len * input_dim`) this router accepts.
    pub fn window_len(&self) -> usize {
        self.shape.seq_len * self.shape.input_dim
    }

    // ---- streaming sessions (DESIGN.md §11) --------------------------

    /// The shared session store (tests, server stats).
    pub fn sessions(&self) -> &Arc<SessionStore> {
        &self.sessions
    }

    /// Open a streaming session and pin it to an engine pool: int8
    /// sessions pin to the quant pool (PR 4's precision contract —
    /// int8 is entered only by explicit request), f32 sessions to the
    /// first stream-capable non-quant engine in registration order.
    /// The h/c state is allocated in the store, zeroed, always f32.
    pub fn open_session(&self, precision: Precision) -> Result<SessionInfo> {
        let target = match precision {
            Precision::Int8 => self
                .stream_targets
                .iter()
                .copied()
                .find(|t| matches!(t, Target::CpuQuant))
                .ok_or_else(|| anyhow!("no quantized streaming engine registered"))?,
            Precision::F32 => self
                .stream_targets
                .iter()
                .copied()
                .find(|t| !matches!(t, Target::CpuQuant))
                .ok_or_else(|| anyhow!("no f32-capable streaming engine registered"))?,
        };
        let id = self.sessions.open(self.shape, precision, target);
        self.metrics.sessions_open.fetch_add(1, Ordering::Relaxed);
        Ok(SessionInfo {
            id,
            target: crate::coordinator::policy::target_label(target),
            ttl: self.sessions.ttl(),
        })
    }

    /// Submit a stream chunk (flat `[steps, input_dim]` frames, one or
    /// more steps); returns the reply receiver.
    pub fn submit_stream(
        &self,
        session: u64,
        frames: Vec<f32>,
        id: Option<u64>,
    ) -> Result<mpsc::Receiver<Result<StreamReply, ServeError>>> {
        let dim = self.shape.input_dim;
        if frames.is_empty() || frames.len() % dim != 0 {
            return Err(anyhow!(
                "stream chunk of {} values is not a positive multiple of input_dim {dim}",
                frames.len()
            ));
        }
        let steps = frames.len() / dim;
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(SchedMsg::Stream(StreamRequest {
                session,
                frames,
                steps,
                id,
                enqueued: Instant::now(),
                reply: ReplySink::channel(rtx),
            }))
            .map_err(|_| anyhow!("router gone"))?;
        Ok(rrx)
    }

    /// Blocking incremental classify: advance `session` through the
    /// given frames and return per-step classes + logits. Typed session
    /// failures ([`ServeError::SessionNotFound`] /
    /// [`ServeError::SessionExpired`]) surface as downcastable errors,
    /// same as the classify path.
    pub fn classify_stream(
        &self,
        session: u64,
        frames: Vec<f32>,
        id: Option<u64>,
    ) -> Result<StreamReply> {
        let rrx = self.submit_stream(session, frames, id)?;
        rrx.recv().context("router dropped stream reply")?.map_err(anyhow::Error::new)
    }

    /// Close a session; returns the steps it consumed. Closing an
    /// unknown (or already-evicted) session is
    /// [`ServeError::SessionNotFound`].
    pub fn close_session(&self, session: u64) -> Result<u64> {
        match self.sessions.close(session) {
            Some(steps) => {
                self.metrics.sessions_open.fetch_sub(1, Ordering::Relaxed);
                Ok(steps)
            }
            None => Err(anyhow::Error::new(ServeError::SessionNotFound(session))),
        }
    }
}

impl Drop for Joiner {
    fn drop(&mut self) {
        // Router thread exits when the last sender drops; just join.
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Fluent constructor for [`Router`] — the only way to build one.
///
/// Defaults: paper-default [`ModelShape`], cost-model policy, 2 ms
/// batching deadline, 4 CPU threads, a 256-request admission bound, a
/// 4-batch work queue per engine pool, a fresh simulated Nexus 5. At
/// least one engine is required: either the standard set via
/// [`RouterBuilder::manifest`] or custom ones via [`RouterBuilder::engine`].
pub struct RouterBuilder {
    shape: ModelShape,
    policy: OffloadPolicy,
    max_wait: Duration,
    cpu_threads: usize,
    max_queue: usize,
    pool_depth: usize,
    session_ttl: Duration,
    session_shards: usize,
    device: Option<DeviceState>,
    registry: EngineRegistry,
    fault_plan: Option<crate::faults::FaultPlan>,
    breaker: crate::coordinator::health::BreakerConfig,
    watchdog: Option<Duration>,
}

impl Default for RouterBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl RouterBuilder {
    pub fn new() -> Self {
        Self {
            shape: ModelShape::default(),
            policy: OffloadPolicy::CostModel,
            max_wait: Duration::from_millis(2),
            cpu_threads: 4,
            max_queue: 256,
            pool_depth: 4,
            session_ttl: Duration::from_secs(30),
            session_shards: 16,
            device: None,
            registry: EngineRegistry::new(),
            fault_plan: None,
            breaker: crate::coordinator::health::BreakerConfig::default(),
            watchdog: Some(Duration::from_secs(2)),
        }
    }

    /// Wrap registered engines in [`crate::faults::FaultyEngine`]s per
    /// this plan at build time (chaos testing / `--fault-plan`). Engines
    /// the plan does not mention run untouched.
    pub fn fault_plan(mut self, plan: crate::faults::FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Circuit-breaker tuning (DESIGN.md §15): consecutive failures that
    /// trip a pool's breaker open, and how long it stays open before a
    /// half-open probe is allowed. Defaults: 5 failures, 1 s cooldown.
    pub fn breaker(mut self, failure_threshold: u32, cooldown: Duration) -> Self {
        self.breaker.failure_threshold = failure_threshold.max(1);
        self.breaker.cooldown = cooldown;
        self
    }

    /// Per-dispatch watchdog timeout (default 2 s): an engine call
    /// running longer is reclaimed — its batch fails over, its stream
    /// gets a typed error, and the pool's breaker opens. Zero disables
    /// the watchdog.
    pub fn watchdog(mut self, timeout: Duration) -> Self {
        self.watchdog = if timeout.is_zero() { None } else { Some(timeout) };
        self
    }

    /// Idle TTL for streaming sessions (default 30 s): a session
    /// untouched for this long is evicted — lazily at the next lookup
    /// or by the scheduler's periodic sweep.
    pub fn session_ttl(mut self, ttl: Duration) -> Self {
        self.session_ttl = ttl;
        self
    }

    /// Lock stripes in the session store (default 16, rounded up to a
    /// power of two).
    pub fn session_shards(mut self, shards: usize) -> Self {
        self.session_shards = shards;
        self
    }

    /// Model shape served by this router (set BEFORE `.manifest(..)`).
    pub fn shape(mut self, shape: ModelShape) -> Self {
        self.shape = shape;
        self
    }

    /// Offload policy (default: cost model).
    pub fn policy(mut self, policy: OffloadPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Simulated device state shared with callers (default: idle Nexus 5).
    pub fn device(mut self, device: DeviceState) -> Self {
        self.device = Some(device);
        self
    }

    /// Batching deadline: how long the oldest request may wait.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Admission bound: requests beyond this many pending in the
    /// scheduler queue are rejected immediately with
    /// [`ServeError::Overloaded`] (default 256).
    pub fn max_queue(mut self, max_queue: usize) -> Self {
        self.max_queue = max_queue.max(1);
        self
    }

    /// Bound on each engine pool's work queue, in batches (default 4).
    /// When every pool is saturated the scheduler keeps batches queued
    /// (deadlines ticking) and lets admission shed the overflow.
    pub fn pool_depth(mut self, pool_depth: usize) -> Self {
        self.pool_depth = pool_depth.max(1);
        self
    }

    /// Threads for the native multi-thread CPU engine (set BEFORE
    /// `.manifest(..)`).
    pub fn cpu_threads(mut self, threads: usize) -> Self {
        self.cpu_threads = threads.max(1);
        self
    }

    /// Register a custom engine; replaces any registered engine of the
    /// same target kind. Registration order is failover order.
    pub fn engine(mut self, engine: Box<dyn Engine>) -> Self {
        self.registry.register(engine);
        self
    }

    /// Register the standard engine set from the AOT artifacts: the PJRT
    /// GPU engine, native single- and multi-thread CPU engines, and the
    /// int8 quantized CPU engine (packed once here), all sharing the
    /// artifact weights. The quant engine is reachable only through an
    /// explicit `precision: int8` / target override — never by policy
    /// or by another batch's failover.
    pub fn manifest(mut self, manifest: &Manifest, runtime: Runtime) -> Result<Self> {
        let shape = self.shape;
        let batches = manifest.batches_for(shape);
        if batches.is_empty() {
            return Err(anyhow!(
                "no compiled variants for shape {shape:?}; run `make artifacts`"
            ));
        }
        let weights_file = manifest
            .variant_for(shape, batches[0])
            .context("variant for smallest batch")?
            .weights
            .clone();
        let wf = WeightFile::load(manifest.path(&weights_file))?;
        let native = Arc::new(LstmModel::from_weight_file(shape, &wf)?);
        let threads = self.cpu_threads;
        self.registry.register(Box::new(PjrtEngine::new(manifest, runtime, shape)?));
        self.registry.register(Box::new(CpuMultiEngine::new(Arc::clone(&native), threads)));
        self.registry.register(Box::new(CpuQuantEngine::from_f32(&native)));
        self.registry.register(Box::new(CpuSingleEngine::new(native)));
        Ok(self)
    }

    /// Spawn the engine pools and the scheduler thread.
    pub fn build(self) -> Result<Router> {
        if self.registry.is_empty() {
            return Err(anyhow!(
                "router needs at least one engine: call .manifest(..) or .engine(..)"
            ));
        }
        let device =
            self.device.unwrap_or_else(|| DeviceState::new(DeviceProfile::nexus5()));
        // Chaos wrapping happens LAST, at the registry boundary, so the
        // scheduler, pools, and health tracking see an injected fault
        // exactly as they would a real engine failure (DESIGN.md §15).
        let registry = match &self.fault_plan {
            Some(plan) if !plan.is_empty() => {
                let mut wrapped = EngineRegistry::new();
                for e in self.registry.into_engines() {
                    wrapped.register(plan.wrap(e));
                }
                wrapped
            }
            _ => self.registry,
        };
        // Batch sizes the collector may form: the union of what the
        // engines can execute. Engines that accept any batch contribute
        // nothing; if only such engines are registered, use a dyadic
        // ladder so burst traffic still batches.
        let mut batches: Vec<usize> = registry
            .iter()
            .flat_map(|e| e.supported_batches().iter().copied())
            .collect();
        if batches.is_empty() {
            batches = vec![1, 2, 4, 8];
        }
        batches.sort_unstable();
        batches.dedup();

        let metrics = Arc::new(Metrics::new());
        let sessions =
            Arc::new(SessionStore::with_shards(self.session_ttl, self.session_shards));
        // Which pools can serve streams is fixed at build: captured here,
        // consulted at every open_session to pick the affinity pin.
        let stream_targets: Vec<Target> = registry
            .iter()
            .filter(|e| e.supports_streaming())
            .map(|e| e.target())
            .collect();
        let labels: Vec<&'static str> = registry.iter().map(|e| e.label()).collect();
        let health = Arc::new(HealthRegistry::new(labels, self.breaker, Arc::clone(&metrics)));
        let pools = EnginePools::start(
            registry,
            device.clone(),
            Arc::clone(&metrics),
            Arc::clone(&sessions),
            self.shape,
            self.pool_depth,
            Arc::clone(&health),
            self.watchdog,
        )?;
        let (tx, rx) = mpsc::channel::<SchedMsg>();
        // Sweep cadence: a fraction of the TTL so an abandoned session
        // is reclaimed promptly, clamped away from busy-looping.
        let sweep_every = (self.session_ttl / 4)
            .clamp(Duration::from_millis(10), Duration::from_secs(1));
        let scheduler = Scheduler {
            rx,
            collector: BatchCollector::new(batches, self.max_wait),
            queue: VecDeque::new(),
            stream_queue: VecDeque::new(),
            affinity: HashMap::new(),
            sessions: Arc::clone(&sessions),
            sweep_every,
            last_sweep: Instant::now(),
            pools,
            device: device.clone(),
            metrics: Arc::clone(&metrics),
            shape: self.shape,
            policy: self.policy,
            max_wait: self.max_wait,
            max_queue: self.max_queue,
            decisions: DecisionCache::new(),
            health,
        };
        let handle = std::thread::Builder::new()
            .name("mobirnn-scheduler".into())
            .spawn(move || scheduler.run())
            .context("spawning scheduler")?;
        Ok(Router {
            tx,
            metrics,
            device,
            shape: self.shape,
            sessions,
            stream_targets: Arc::new(stream_targets),
            joiner: Arc::new(Joiner { handle: Mutex::new(Some(handle)) }),
        })
    }
}

/// The scheduler: the router thread's entire job since the pipelined
/// refactor. Never executes a batch — it admits, batches, decides, and
/// dispatches to the engine pools.
struct Scheduler {
    rx: mpsc::Receiver<SchedMsg>,
    collector: BatchCollector,
    queue: VecDeque<ServeRequest>,
    /// Stream chunks awaiting dispatch to their pinned pool. Streams
    /// never batch (each chunk is one session's private state advance),
    /// so they bypass the collector; FIFO order preserves per-session
    /// step order for a client that pipelines chunks.
    stream_queue: VecDeque<StreamRequest>,
    /// Session affinity map (DESIGN.md §11): the scheduler's view of
    /// which pool each in-flight stream is pinned to, refreshed from
    /// the authoritative `Session::target` on every dispatch and pruned
    /// on expiry/close. Kept so the sweep can say which streams it
    /// dropped and introspection stays O(1) on the scheduler thread.
    affinity: HashMap<u64, Target>,
    sessions: Arc<SessionStore>,
    sweep_every: Duration,
    last_sweep: Instant,
    pools: EnginePools,
    device: DeviceState,
    metrics: Arc<Metrics>,
    shape: ModelShape,
    policy: OffloadPolicy,
    max_wait: Duration,
    max_queue: usize,
    decisions: DecisionCache,
    /// Shared with the pool workers (success/failure accounting) and the
    /// watchdog (force-open); the scheduler reads breaker state before
    /// dispatch and for brownout / health-aware pricing (DESIGN.md §15).
    health: Arc<HealthRegistry>,
}

impl Scheduler {
    fn run(mut self) {
        let mut last_tick = Instant::now();
        loop {
            // Virtual device time advances with real time (queue drain).
            let now = Instant::now();
            self.device.advance_virtual(now.duration_since(last_tick).as_nanos() as u64);
            last_tick = now;

            // Reclaim abandoned sessions on a TTL-fraction cadence.
            if now.duration_since(self.last_sweep) >= self.sweep_every {
                self.sweep_sessions();
                self.last_sweep = now;
            }

            // Wait for work or the batching deadline.
            let timeout = self
                .collector
                .time_to_deadline(now)
                .unwrap_or(Duration::from_millis(50))
                .min(self.sweep_every);
            match self.rx.recv_timeout(timeout) {
                Ok(msg) => {
                    self.admit_msg(msg);
                    // Opportunistically drain whatever is already queued.
                    while let Ok(msg) = self.rx.try_recv() {
                        self.admit_msg(msg);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Serve the tail (poll "in the future" so every
                    // deadline fires), then stop the pools — they drain
                    // their queues before honoring the shutdown marker.
                    while self.collector.pending() > 0 {
                        if !self.dispatch_once(Instant::now() + 2 * self.max_wait) {
                            std::thread::sleep(POOL_FULL_BACKOFF);
                        }
                    }
                    while !self.stream_queue.is_empty() {
                        if !self.dispatch_streams() {
                            std::thread::sleep(POOL_FULL_BACKOFF);
                        }
                    }
                    self.metrics.queue_depth.store(0, Ordering::Relaxed);
                    self.pools.shutdown();
                    return;
                }
            }
            let streams_placed = self.dispatch_streams();
            if !self.dispatch_once(Instant::now()) || !streams_placed {
                // Every pool is saturated: back off briefly instead of
                // spinning on the already-due batching deadline.
                std::thread::sleep(POOL_FULL_BACKOFF);
            }
        }
    }

    fn admit_msg(&mut self, msg: SchedMsg) {
        match msg {
            SchedMsg::Classify(req) => self.admit(req),
            SchedMsg::Stream(req) => self.admit_stream(req),
        }
    }

    /// Bounded admission: beyond `max_queue` pending requests the
    /// overflow is shed NOW with a typed error, not queued to die.
    fn admit(&mut self, req: ServeRequest) {
        if self.queue.len() >= self.max_queue {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            let _ = req.reply.send(Err(ServeError::Overloaded));
            return;
        }
        self.collector.push(req.enqueued);
        self.queue.push_back(req);
        self.metrics.queue_depth.store(self.queue.len() as u64, Ordering::Relaxed);
    }

    /// Stream chunks share the admission bound (a stream queue allowed
    /// to grow without limit would starve classify traffic of the same
    /// protection).
    fn admit_stream(&mut self, req: StreamRequest) {
        if self.stream_queue.len() >= self.max_queue {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            let _ = req.reply.send(Err(ServeError::Overloaded));
            return;
        }
        self.stream_queue.push_back(req);
    }

    /// Evict TTL-lapsed sessions and drop their affinity entries; also
    /// prune affinity entries whose session was closed by the caller
    /// (close happens on the caller's thread, not here).
    fn sweep_sessions(&mut self) {
        let evicted = self.sessions.evict_expired(self.sessions.now_ns());
        if !evicted.is_empty() {
            self.metrics.sessions_expired.fetch_add(evicted.len() as u64, Ordering::Relaxed);
            self.metrics.sessions_open.fetch_sub(evicted.len() as u64, Ordering::Relaxed);
            for id in &evicted {
                self.affinity.remove(id);
            }
        }
        let sessions = &self.sessions;
        self.affinity.retain(|id, _| sessions.contains(*id));
    }

    /// Dispatch every queued stream chunk to its session's pinned pool
    /// (failover order after that). Returns `false` when a chunk could
    /// not be placed because every eligible pool was saturated — it
    /// stays at the queue front and the caller backs off. Session
    /// lookup happens per dispatch, so TTL expiry applies to queued
    /// chunks too and a migrated pin takes effect on the next chunk.
    fn dispatch_streams(&mut self) -> bool {
        while let Some(req) = self.stream_queue.pop_front() {
            let now_ns = self.sessions.now_ns();
            let target = match self.sessions.target_of(req.session, now_ns) {
                Ok(t) => t,
                Err(SessionError::NotFound(id)) => {
                    self.affinity.remove(&id);
                    let _ = req.reply.send(Err(ServeError::SessionNotFound(id)));
                    continue;
                }
                Err(SessionError::Expired(id)) => {
                    self.metrics.sessions_expired.fetch_add(1, Ordering::Relaxed);
                    self.metrics.sessions_open.fetch_sub(1, Ordering::Relaxed);
                    self.affinity.remove(&id);
                    let _ = req.reply.send(Err(ServeError::SessionExpired(id)));
                    continue;
                }
            };
            self.affinity.insert(req.session, target);
            let job = StreamJob { req, target, tried: 0 };
            if let Err(job) = self.pools.dispatch_stream(job, &self.metrics) {
                self.stream_queue.push_front(job.req);
                return false;
            }
        }
        true
    }

    /// Form and dispatch at most one batch. Returns `false` when a
    /// formed batch could not be placed because every pool's queue was
    /// full (the batch is restored, the caller backs off).
    fn dispatch_once(&mut self, now: Instant) -> bool {
        let Some(plan) = self.collector.poll(now) else { return true };

        // Pop the batch members, dropping the ones whose caller has
        // already timed out: the scheduler knows `enqueued` and the
        // deadline, so computing a dead batch slot would be pure waste.
        let mut live: Vec<ServeRequest> = Vec::with_capacity(plan.take);
        for _ in 0..plan.take {
            let Some(req) = self.queue.pop_front() else { break };
            let expired =
                req.opts.deadline.is_some_and(|d| now.duration_since(req.enqueued) >= d);
            if expired {
                self.metrics.expired.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(ServeError::DeadlineExceeded));
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            self.metrics.queue_depth.store(self.queue.len() as u64, Ordering::Relaxed);
            return true;
        }

        // Precision is a caller contract (DESIGN.md §10): a batch must
        // never mix exact and int8 members, or the earliest member
        // would silently decide the numerics for the rest. A request is
        // int8-class through EITHER knob — the precision field or an
        // explicit cpu-quant target override. Keep the head run of one
        // class; the tail goes back to the queue FRONT (original
        // arrival instants — deadlines keep ticking) and forms its own
        // batch on the next cycle.
        let wants_int8 = |r: &ServeRequest| {
            matches!(r.opts.precision, Some(Precision::Int8))
                || matches!(r.opts.target, Some(Target::CpuQuant))
        };
        let head_int8 = wants_int8(&live[0]);
        let split =
            live.iter().position(|r| wants_int8(r) != head_int8).unwrap_or(live.len());
        if split < live.len() {
            let rest = live.split_off(split);
            self.collector.restore(rest.iter().map(|r| r.enqueued));
            for req in rest.into_iter().rev() {
                self.queue.push_front(req);
            }
        }
        self.metrics.queue_depth.store(self.queue.len() as u64, Ordering::Relaxed);

        // Re-plan padding for the survivors (expiry or the precision
        // split may have shrunk the batch below the planned size).
        let padded_to = plan_batch(live.len(), self.collector.compiled_sizes())
            .map_or(live.len(), |p| p.padded_to);

        // Build the padded [B, T, D] tensor.
        let shape = self.shape;
        let window_len = shape.seq_len * shape.input_dim;
        let mut data = Vec::with_capacity(padded_to * window_len);
        for r in &live {
            data.extend_from_slice(&r.window);
        }
        data.resize(padded_to * window_len, 0.0);
        let x = Tensor::new(vec![padded_to, shape.seq_len, shape.input_dim], data);

        // Offload decision: an explicit per-request target override
        // wins; next an int8 batch (uniform by the split above) pins
        // the quantized engine (the policy never picks it on its own —
        // DESIGN.md §10); otherwise the policy decides on current load
        // — background knobs plus the REAL per-pool in-flight depth,
        // so the cost model steers away from an engine that is already
        // saturated.
        let override_target = live.iter().find_map(|r| r.opts.target);
        let mut target = match override_target {
            Some(t) => t,
            None if head_int8 => Target::CpuQuant,
            None => {
                let load = LoadSnapshot {
                    gpu_util: self.device.effective_gpu_util(),
                    cpu_util: self.device.cpu_util(),
                    gpu_inflight: self.metrics.inflight.gpu.load(Ordering::Relaxed),
                    cpu_inflight: self.metrics.inflight.cpu.load(Ordering::Relaxed)
                        + self.metrics.inflight.cpu_multi.load(Ordering::Relaxed)
                        + self.metrics.inflight.cpu_quant.load(Ordering::Relaxed),
                };
                let profile = self.device.profile();
                if matches!(self.policy, OffloadPolicy::CostModel)
                    && self.health.any_non_closed()
                {
                    // Health-aware pricing (DESIGN.md §15): a pool whose
                    // breaker is open inside its cooldown is infinite
                    // cost — it simply drops out of the candidate set.
                    // Bypasses the DecisionCache because breaker state
                    // is not part of its key.
                    OffloadPolicy::candidates(profile)
                        .into_iter()
                        .filter(|&t| self.pools.kind_dispatchable(t))
                        .min_by_key(|&t| {
                            simulate_inference(
                                profile,
                                shape,
                                padded_to,
                                t,
                                load.effective_util(t),
                            )
                        })
                        .unwrap_or(Target::CpuSingle)
                } else {
                    self.decisions.decide(&self.policy, profile, shape, padded_to, load)
                }
            }
        };

        // Brownout-or-shed gate (DESIGN.md §15): when every pool in the
        // decided target's failover order has its breaker open, either
        // degrade the batch to the int8 tier — only if every member
        // opted in via `allow_degraded`, the batch is f32 with no
        // explicit target override, and a quant pool is admitting — or
        // shed it NOW with a typed error. Never queue it to die.
        let mut degraded = None;
        if self.pools.no_pool_available(target) {
            let all_opted = live.iter().all(|r| r.opts.allow_degraded);
            if !head_int8
                && override_target.is_none()
                && all_opted
                && self.pools.kind_dispatchable(Target::CpuQuant)
            {
                target = Target::CpuQuant;
                degraded = Some("int8");
            } else {
                self.metrics.shed.fetch_add(live.len() as u64, Ordering::Relaxed);
                for req in live {
                    let _ = req.reply.send(Err(ServeError::Overloaded));
                }
                return true;
            }
        }

        // The batch's retry/deadline budget is the EARLIEST member
        // deadline: failover stops retrying once any member would be
        // served a dead answer (DESIGN.md §15).
        let deadline =
            live.iter().filter_map(|r| r.opts.deadline.map(|d| r.enqueued + d)).min();

        let job = BatchJob {
            x,
            reqs: live,
            target,
            padded_to,
            tried: 0,
            deadline,
            attempt: 0,
            degraded,
        };
        match self.pools.dispatch(job, &self.metrics) {
            Ok(()) => true,
            Err(job) => {
                // Every pool saturated: the requests go back to the
                // FRONT of the queue with their true arrival instants
                // (deadlines keep ticking); admission sheds overflow.
                self.collector.restore(job.reqs.iter().map(|r| r.enqueued));
                for req in job.reqs.into_iter().rev() {
                    self.queue.push_front(req);
                }
                self.metrics.queue_depth.store(self.queue.len() as u64, Ordering::Relaxed);
                false
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::testutil::{FixedEngine, NanEngine, SlowEngine};
    use crate::har;
    use crate::simulator::Factorization;

    fn setup(policy: OffloadPolicy) -> Option<(Router, Manifest)> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let man = Manifest::load(dir).unwrap();
        let rt = Runtime::start(&man).unwrap();
        let router = Router::builder()
            .policy(policy)
            .max_wait(Duration::from_millis(1))
            .manifest(&man, rt)
            .unwrap()
            .build()
            .unwrap();
        Some((router, man))
    }

    fn small_shape() -> ModelShape {
        ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 }
    }

    /// A router over arbitrary fake engines — exercises the builder, the
    /// scheduler and the engine pools without artifacts.
    fn boxed_router(policy: OffloadPolicy, engines: Vec<Box<dyn Engine>>) -> Router {
        let mut b = Router::builder()
            .shape(small_shape())
            .policy(policy)
            .max_wait(Duration::from_millis(1));
        for e in engines {
            b = b.engine(e);
        }
        b.build().unwrap()
    }

    fn fixed_router(policy: OffloadPolicy, engines: Vec<FixedEngine>) -> Router {
        boxed_router(
            policy,
            engines.into_iter().map(|e| Box::new(e) as Box<dyn Engine>).collect(),
        )
    }

    #[test]
    fn builder_requires_an_engine() {
        let err = Router::builder().build().unwrap_err().to_string();
        assert!(err.contains("engine"), "{err}");
    }

    #[test]
    fn fixed_engine_round_trip_without_artifacts() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.class, 1, "FixedEngine always predicts class 1");
        // Policy may ask for the GPU; the registry fails over to the only
        // engine present without counting an error.
        assert_eq!(reply.target, "cpu");
        assert!(reply.sim_ns > 0);
        assert_eq!(router.metrics.errors.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn engine_failover_is_generic() {
        let router = fixed_router(
            OffloadPolicy::Static(Target::Gpu(Factorization::Coarse)),
            vec![
                FixedEngine::failing(Target::Gpu(Factorization::Coarse)),
                FixedEngine::new(Target::CpuMulti(4)),
            ],
        );
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.target, "cpu-multi", "failover must reach the next engine");
        assert_eq!(router.metrics.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn all_engines_failing_surfaces_serve_error() {
        let router = fixed_router(
            OffloadPolicy::Static(Target::CpuSingle),
            vec![FixedEngine::failing(Target::CpuSingle)],
        );
        let outcome = router.submit(vec![0.0; 30]).unwrap().recv().unwrap();
        match outcome {
            Err(ServeError::EngineFailure(msg)) => assert!(msg.contains("failed"), "{msg}"),
            other => panic!("expected EngineFailure, got {other:?}"),
        }
        assert!(router.metrics.errors.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn options_carry_id_and_deadline() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        let reply = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions { id: Some(99), ..Default::default() },
            )
            .unwrap();
        assert_eq!(reply.id, Some(99));

        // A zero deadline elapses before the 1 ms batching wait.
        let err = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions { deadline: Some(Duration::ZERO), ..Default::default() },
            )
            .unwrap_err();
        assert!(
            err.downcast_ref::<ServeError>() == Some(&ServeError::DeadlineExceeded),
            "{err:#}"
        );
    }

    #[test]
    fn classify_roundtrip_gpu() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(4, 11);
        for i in 0..4 {
            let reply = router.classify(ds.window(i).to_vec()).unwrap();
            assert!(reply.class < har::NUM_CLASSES);
            assert_eq!(reply.logits.len(), har::NUM_CLASSES);
            assert_eq!(reply.target, "gpu", "idle device should offload");
            assert!(reply.sim_ns > 0);
        }
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn classify_cpu_matches_gpu_numerics() {
        // The offload decision must not change answers: native CPU logits
        // track the XLA logits within fp tolerance.
        let Some((gpu_router, man)) = setup(OffloadPolicy::Static(Target::Gpu(
            Factorization::Coarse,
        ))) else {
            return;
        };
        let rt = Runtime::start(&man).unwrap();
        let cpu_router = Router::builder()
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(1))
            .manifest(&man, rt)
            .unwrap()
            .build()
            .unwrap();
        let ds = har::generate(6, 13);
        for i in 0..6 {
            let g = gpu_router.classify(ds.window(i).to_vec()).unwrap();
            let c = cpu_router.classify(ds.window(i).to_vec()).unwrap();
            assert_eq!(g.target, "gpu");
            assert_eq!(c.target, "cpu");
            assert_eq!(g.class, c.class, "window {i}: targets disagree");
            for (a, b) in g.logits.iter().zip(&c.logits) {
                assert!((a - b).abs() < 1e-3, "logit drift {a} vs {b}");
            }
        }
    }

    #[test]
    fn per_request_target_override_beats_policy() {
        // Idle device: the cost model would pick the GPU, but the
        // override pins this request to the single-thread CPU engine.
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(2, 15);
        let forced = router
            .classify_with(
                ds.window(0).to_vec(),
                ClassifyOptions { target: Some(Target::CpuSingle), ..Default::default() },
            )
            .unwrap();
        assert_eq!(forced.target, "cpu", "override must bypass the policy");
        let free = router.classify(ds.window(1).to_vec()).unwrap();
        assert_eq!(free.target, "gpu", "non-overridden requests still follow the policy");
    }

    #[test]
    fn high_load_switches_to_cpu() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        router.device.set_gpu_util(0.9);
        router.device.set_cpu_util(0.9);
        let ds = har::generate(1, 17);
        let reply = router.classify(ds.window(0).to_vec()).unwrap();
        assert_ne!(reply.target, "gpu", "§4.5: loaded GPU must not be chosen");
    }

    #[test]
    fn submit_rejects_wrong_window() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        assert!(router.submit(vec![0.0; 7]).is_err());
    }

    #[test]
    fn batches_form_under_burst() {
        let Some((router, _)) = setup(OffloadPolicy::CostModel) else { return };
        let ds = har::generate(16, 19);
        let rxs: Vec<_> =
            (0..16).map(|i| router.submit(ds.window(i).to_vec()).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(r.batch_size >= 1);
        }
        let batches = router.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 16, "burst should batch: {batches} batches for 16 reqs");
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 16);
    }

    // ---- pipelined dispatch (scheduler + engine pools, DESIGN.md §9) --

    #[test]
    fn nan_logits_never_panic_and_follow_first_finite_max() {
        // Regression: the reply path used max_by(partial_cmp().unwrap()),
        // which PANICS on NaN logits. The pool must apply the crate-wide
        // "first finite max" rule instead.
        let router = boxed_router(
            OffloadPolicy::Static(Target::CpuSingle),
            vec![Box::new(NanEngine::new(Target::CpuSingle))],
        );
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.class, 2, "first finite max of [NaN,1,7,0.5,NaN,0]");
        assert!(reply.logits[0].is_nan(), "raw logits pass through untouched");
    }

    #[test]
    fn gpu_and_cpu_batches_execute_concurrently() {
        // The acceptance bar: with two engines registered, a GPU-target
        // batch and a CPU-target batch provably overlap in time. The old
        // single-thread router serialized them (~2 × delay end-to-end).
        let delay = Duration::from_millis(150);
        let gpu = SlowEngine::new(Target::Gpu(Factorization::Coarse), delay);
        let cpu = SlowEngine::new(Target::CpuSingle, delay);
        let gpu_spans = Arc::clone(&gpu.spans);
        let cpu_spans = Arc::clone(&cpu.spans);
        let router =
            boxed_router(OffloadPolicy::CostModel, vec![Box::new(gpu), Box::new(cpu)]);

        let rx_gpu = router
            .submit_with(
                vec![0.0; 30],
                ClassifyOptions {
                    target: Some(Target::Gpu(Factorization::Coarse)),
                    ..Default::default()
                },
            )
            .unwrap();
        // Let the first batch form (1 ms max_wait) and start executing,
        // then send the CPU-target request while the GPU pool is busy.
        std::thread::sleep(Duration::from_millis(30));
        let rx_cpu = router
            .submit_with(
                vec![0.0; 30],
                ClassifyOptions { target: Some(Target::CpuSingle), ..Default::default() },
            )
            .unwrap();
        rx_gpu.recv().unwrap().unwrap();
        rx_cpu.recv().unwrap().unwrap();

        let (g0, g1) = gpu_spans.lock().unwrap()[0];
        let (c0, c1) = cpu_spans.lock().unwrap()[0];
        assert!(
            g0 < c1 && c0 < g1,
            "GPU and CPU batches must overlap: gpu {:?} cpu {:?}",
            g1.duration_since(g0),
            c1.duration_since(c0),
        );
    }

    #[test]
    fn admission_bound_sheds_with_overloaded() {
        // Flood a tiny queue in front of a slow engine: overflow must be
        // rejected NOW as Overloaded while admitted requests still serve.
        let router = Router::builder()
            .shape(small_shape())
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(1))
            .max_queue(2)
            .pool_depth(1)
            .engine(Box::new(SlowEngine::new(
                Target::CpuSingle,
                Duration::from_millis(100),
            )))
            .build()
            .unwrap();
        let rxs: Vec<_> = (0..32).map(|_| router.submit(vec![0.0; 30]).unwrap()).collect();
        let mut shed = 0u64;
        let mut served = 0u64;
        for rx in rxs {
            match rx.recv().unwrap() {
                Ok(reply) => {
                    assert_eq!(reply.class, 1);
                    served += 1;
                }
                Err(ServeError::Overloaded) => shed += 1,
                Err(other) => panic!("unexpected error: {other:?}"),
            }
        }
        assert!(shed > 0, "32 requests against max_queue=2 must shed");
        assert!(served > 0, "admitted requests must still be served");
        assert_eq!(router.metrics.shed.load(Ordering::Relaxed), shed);
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), served);
    }

    #[test]
    fn failover_re_enqueues_across_pools() {
        // A pool-level failure re-enqueues the batch on the next pool in
        // failover order — twice here — instead of failing inline.
        let router = boxed_router(
            OffloadPolicy::Static(Target::Gpu(Factorization::Coarse)),
            vec![
                Box::new(FixedEngine::failing(Target::Gpu(Factorization::Coarse))),
                Box::new(FixedEngine::failing(Target::CpuMulti(4))),
                Box::new(FixedEngine::new(Target::CpuSingle)),
            ],
        );
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.target, "cpu", "job must hop gpu → cpu-multi → cpu");
        assert_eq!(router.metrics.errors.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn expired_requests_are_dropped_at_dispatch() {
        // max_wait 50 ms means a lone request dispatches at +50 ms; its
        // 5 ms deadline has long elapsed by then, so the scheduler must
        // drop it before tensor assembly — no batch, no engine call.
        let router = Router::builder()
            .shape(small_shape())
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(50))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let rx = router
            .submit_with(
                vec![0.0; 30],
                ClassifyOptions {
                    deadline: Some(Duration::from_millis(5)),
                    ..Default::default()
                },
            )
            .unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::DeadlineExceeded) => {}
            other => panic!("expected deadline drop, got {other:?}"),
        }
        assert_eq!(router.metrics.expired.load(Ordering::Relaxed), 1);
        assert_eq!(
            router.metrics.batches.load(Ordering::Relaxed),
            0,
            "no batch may form for an expired request"
        );
        assert_eq!(router.metrics.requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn int8_precision_routes_to_quant_engine() {
        // precision: int8 pins the batch to the quant pool; requests
        // without it keep following the policy/override path. Uses fake
        // engines so only ROUTING is under test here (numeric parity is
        // tests/quant.rs's job).
        let quant = FixedEngine::new(Target::CpuQuant);
        let quant_calls = Arc::clone(&quant.calls);
        let f32e = FixedEngine::new(Target::CpuSingle);
        let f32_calls = Arc::clone(&f32e.calls);
        let router = fixed_router(
            OffloadPolicy::Static(Target::CpuSingle),
            vec![f32e, quant],
        );
        let reply = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions { precision: Some(Precision::Int8), ..Default::default() },
            )
            .unwrap();
        assert_eq!(reply.target, "cpu-quant");
        assert_eq!(quant_calls.load(Ordering::Relaxed), 1);
        let reply = router.classify(vec![0.0; 30]).unwrap();
        assert_eq!(reply.target, "cpu", "default precision keeps the policy's engine");
        assert_eq!(f32_calls.load(Ordering::Relaxed), 1);
        // Explicit f32 precision is a no-op relative to the default.
        let reply = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions { precision: Some(Precision::F32), ..Default::default() },
            )
            .unwrap();
        assert_eq!(reply.target, "cpu");
    }

    #[test]
    fn mixed_precision_batch_splits_instead_of_contaminating() {
        // An f32 request and an int8 request arriving in the same
        // batching window must NOT share a batch: the f32 caller never
        // opted into approximate answers. The scheduler splits the
        // formed batch on the precision boundary and re-queues the tail.
        let router = Router::builder()
            .shape(small_shape())
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(40))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .engine(Box::new(FixedEngine::new(Target::CpuQuant)))
            .build()
            .unwrap();
        let rx_f = router.submit(vec![0.0; 30]).unwrap();
        let rx_q = router
            .submit_with(
                vec![0.0; 30],
                ClassifyOptions { precision: Some(Precision::Int8), ..Default::default() },
            )
            .unwrap();
        let f = rx_f.recv().unwrap().unwrap();
        let q = rx_q.recv().unwrap().unwrap();
        assert_eq!(f.target, "cpu", "f32 request must never be served by the quant engine");
        assert_eq!(q.target, "cpu-quant", "int8 request still reaches the quant engine");
        assert_eq!(
            router.metrics.batches.load(Ordering::Relaxed),
            2,
            "mixed-precision arrivals must form two batches"
        );
    }

    #[test]
    fn quant_target_override_also_splits_from_f32_batch() {
        // The int8 class is reachable through the target knob too: a
        // cpu-quant TARGET override in the same window as a plain
        // request must not drag the plain request onto the quant
        // engine (the batch-wide target override would otherwise apply
        // to both).
        let router = Router::builder()
            .shape(small_shape())
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(40))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .engine(Box::new(FixedEngine::new(Target::CpuQuant)))
            .build()
            .unwrap();
        let rx_f = router.submit(vec![0.0; 30]).unwrap();
        let rx_q = router
            .submit_with(
                vec![0.0; 30],
                ClassifyOptions { target: Some(Target::CpuQuant), ..Default::default() },
            )
            .unwrap();
        let f = rx_f.recv().unwrap().unwrap();
        let q = rx_q.recv().unwrap().unwrap();
        assert_eq!(f.target, "cpu", "plain request must not ride a cpu-quant override");
        assert_eq!(q.target, "cpu-quant");
        assert_eq!(router.metrics.batches.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn target_override_beats_precision() {
        let router = fixed_router(
            OffloadPolicy::CostModel,
            vec![FixedEngine::new(Target::CpuSingle), FixedEngine::new(Target::CpuQuant)],
        );
        let reply = router
            .classify_with(
                vec![0.0; 30],
                ClassifyOptions {
                    target: Some(Target::CpuSingle),
                    precision: Some(Precision::Int8),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(reply.target, "cpu", "explicit target wins over precision");
    }

    #[test]
    fn f32_batch_never_fails_over_to_quant_pool() {
        // The f32 engine fails and only the quant pool remains: the
        // batch must FAIL, not silently serve approximate answers.
        let quant = FixedEngine::new(Target::CpuQuant);
        let quant_calls = Arc::clone(&quant.calls);
        let router = fixed_router(
            OffloadPolicy::Static(Target::CpuSingle),
            vec![FixedEngine::failing(Target::CpuSingle), quant],
        );
        let outcome = router.submit(vec![0.0; 30]).unwrap().recv().unwrap();
        assert!(
            matches!(outcome, Err(ServeError::EngineFailure(_))),
            "expected failure, got {outcome:?}"
        );
        assert_eq!(quant_calls.load(Ordering::Relaxed), 0, "quant pool must stay untouched");
    }

    #[test]
    fn inflight_gauges_return_to_zero() {
        let router =
            fixed_router(OffloadPolicy::CostModel, vec![FixedEngine::new(Target::CpuSingle)]);
        for _ in 0..4 {
            router.classify(vec![0.0; 30]).unwrap();
        }
        // classify() is synchronous, so nothing is in flight afterwards.
        assert_eq!(router.metrics.inflight.total(), 0);
        assert_eq!(router.metrics.queue_depth.load(Ordering::Relaxed), 0);
    }
}
