//! Offload policy: where should this batch run?
//!
//! The paper's conclusion (§4.5): "MobiRNN should take into account GPU
//! utilization before offloading tasks to the GPU." Three policies:
//!
//! - [`OffloadPolicy::Static`] — always the given target (the paper's
//!   fixed GPU/CPU bars; baseline for the policy ablation).
//! - [`OffloadPolicy::Threshold`] — GPU below a utilization cutoff,
//!   multi-threaded CPU above it (the simple reading of §4.5).
//! - [`OffloadPolicy::CostModel`] — evaluate the calibrated simulator for
//!   every candidate target under current conditions and take the argmin;
//!   this is the "model-driven scheduler" the paper's future work implies.

use crate::config::ModelShape;
use crate::simulator::{simulate_inference, DeviceProfile, Factorization, Target};

/// Utilization snapshot the policy decides on.
///
/// `gpu_util`/`cpu_util` are the externally-set background knobs (the
/// paper's co-running apps, §4.5). The `*_inflight` fields are REAL
/// serving state: batches currently queued or executing on the engine
/// pools (DESIGN.md §9), so the cost model steers away from an engine
/// that is already saturated by our own dispatches — not just by the
/// simulated background load.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadSnapshot {
    pub gpu_util: f64,
    pub cpu_util: f64,
    /// Batches queued or executing on the GPU engine pool.
    pub gpu_inflight: u64,
    /// Batches queued or executing on the CPU engine pools (single +
    /// multi + quant combined — they share the simulated CPU complex).
    pub cpu_inflight: u64,
}

impl LoadSnapshot {
    /// Utilization the policy prices target `t` at: the background knob
    /// plus [`inflight_pressure`] from batches already in flight on the
    /// pool that would serve it, clamped to 1.
    pub fn effective_util(&self, t: Target) -> f64 {
        let (util, depth) = match t {
            Target::Gpu(_) => (self.gpu_util, self.gpu_inflight),
            _ => (self.cpu_util, self.cpu_inflight),
        };
        (util + inflight_pressure(depth)).min(1.0)
    }
}

/// Extra effective utilization charged per in-flight batch (0.15 each,
/// saturating at +0.6 — four deep batches read as a fully busy engine).
pub fn inflight_pressure(depth: u64) -> f64 {
    (depth as f64 * 0.15).min(0.6)
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OffloadPolicy {
    /// Always run on the given target.
    Static(Target),
    /// GPU while `gpu_util < gpu_threshold`, else multithreaded CPU.
    Threshold { gpu_threshold: f64 },
    /// Argmin of simulated latency over candidate targets. When any
    /// circuit breaker is not closed, the scheduler prices health into
    /// this policy directly (DESIGN.md §15): a pool whose breaker is
    /// open inside its cooldown costs infinity — it drops out of the
    /// candidate set until a half-open probe succeeds.
    CostModel,
}

impl OffloadPolicy {
    /// Candidate targets the cost model ranks. [`Target::CpuQuant`] is
    /// deliberately NOT a candidate even though the simulator prices it
    /// below the f32 CPU (see `cpu_run_int8`): the int8 path is
    /// approximate, and precision is a caller-visible contract
    /// ([`Precision`]) — the policy must never trade answer fidelity
    /// for latency on its own (DESIGN.md §10).
    pub fn candidates(profile: &DeviceProfile) -> [Target; 3] {
        [
            Target::Gpu(Factorization::Coarse),
            Target::CpuMulti(profile.cpu_cores),
            Target::CpuSingle,
        ]
    }

    /// Decide the execution target for a batch of `batch` inferences.
    pub fn decide(
        &self,
        profile: &DeviceProfile,
        shape: ModelShape,
        batch: usize,
        load: LoadSnapshot,
    ) -> Target {
        match *self {
            OffloadPolicy::Static(t) => t,
            OffloadPolicy::Threshold { gpu_threshold } => {
                // In-flight depth counts against the cutoff like render
                // load does: a backed-up GPU pool is a busy GPU (§4.5).
                if load.effective_util(Target::Gpu(Factorization::Coarse)) < gpu_threshold {
                    Target::Gpu(Factorization::Coarse)
                } else {
                    Target::CpuMulti(profile.cpu_cores)
                }
            }
            OffloadPolicy::CostModel => {
                let mut best = Target::CpuSingle;
                let mut best_ns = u64::MAX;
                for t in Self::candidates(profile) {
                    let ns =
                        simulate_inference(profile, shape, batch, t, load.effective_util(t));
                    if ns < best_ns {
                        best_ns = ns;
                        best = t;
                    }
                }
                best
            }
        }
    }

    /// Parse from CLI string: "gpu", "cpu", "cpu-multi", "threshold:0.5",
    /// "cost-model", "fine" (the CUDA-style baseline).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gpu" | "coarse" => Some(Self::Static(Target::Gpu(Factorization::Coarse))),
            "fine" | "cuda" => Some(Self::Static(Target::Gpu(Factorization::Fine))),
            "cpu" | "cpu-single" => Some(Self::Static(Target::CpuSingle)),
            "cpu-multi" | "multithread" => Some(Self::Static(Target::CpuMulti(4))),
            "cost-model" | "auto" => Some(Self::CostModel),
            _ => s
                .strip_prefix("threshold:")
                .and_then(|v| v.parse().ok())
                .map(|gpu_threshold| Self::Threshold { gpu_threshold }),
        }
    }
}

/// Memoizing wrapper around [`OffloadPolicy::decide`].
///
/// The cost model runs three full device simulations per decision
/// (~50–80 µs) — measurable against sub-millisecond batches. Decisions
/// only depend on (batch, load), and load is quantized to 2% buckets
/// (well inside the simulator's calibration error) plus the in-flight
/// depths saturated at 4 (where [`inflight_pressure`] tops out), so a
/// small hash map turns the steady-state decision into a ~100 ns lookup
/// (EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct DecisionCache {
    map: std::collections::HashMap<(usize, u16, u16, u16, u16), Target>,
}

impl DecisionCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quantize a utilization to a 2%-wide bucket id.
    fn bucket(util: f64) -> u16 {
        (util.clamp(0.0, 1.0) * 50.0).round() as u16
    }

    /// Quantize an in-flight depth: pressure saturates at 4 batches, so
    /// deeper queues share one bucket.
    fn depth_bucket(depth: u64) -> u16 {
        depth.min(4) as u16
    }

    pub fn decide(
        &mut self,
        policy: &OffloadPolicy,
        profile: &DeviceProfile,
        shape: ModelShape,
        batch: usize,
        load: LoadSnapshot,
    ) -> Target {
        match policy {
            // Static and threshold policies are already nanosecond-cheap.
            OffloadPolicy::Static(_) | OffloadPolicy::Threshold { .. } => {
                policy.decide(profile, shape, batch, load)
            }
            OffloadPolicy::CostModel => {
                let key = (
                    batch,
                    Self::bucket(load.gpu_util),
                    Self::bucket(load.cpu_util),
                    Self::depth_bucket(load.gpu_inflight),
                    Self::depth_bucket(load.cpu_inflight),
                );
                if let Some(&t) = self.map.get(&key) {
                    return t;
                }
                // Evaluate at the bucket CENTER so every load in the
                // bucket gets the same (representative) answer.
                let centered = LoadSnapshot {
                    gpu_util: key.1 as f64 / 50.0,
                    cpu_util: key.2 as f64 / 50.0,
                    gpu_inflight: key.3 as u64,
                    cpu_inflight: key.4 as u64,
                };
                let t = policy.decide(profile, shape, batch, centered);
                self.map.insert(key, t);
                t
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Human-readable target label (wire protocol + figures).
pub fn target_label(t: Target) -> &'static str {
    match t {
        Target::Gpu(Factorization::Coarse) => "gpu",
        Target::Gpu(Factorization::Fine) => "gpu-fine",
        Target::CpuSingle => "cpu",
        Target::CpuMulti(_) => "cpu-multi",
        Target::CpuQuant => "cpu-quant",
    }
}

/// Inverse of [`target_label`] for wire/CLI target overrides. Thread
/// count for "cpu-multi" is normalized to 4 (the label does not carry
/// it); the engine registry matches on kind, so any count resolves to
/// the one registered multi-thread engine.
pub fn parse_target(s: &str) -> Option<Target> {
    match s {
        "gpu" | "coarse" => Some(Target::Gpu(Factorization::Coarse)),
        "gpu-fine" | "fine" => Some(Target::Gpu(Factorization::Fine)),
        "cpu" | "cpu-single" => Some(Target::CpuSingle),
        "cpu-multi" | "multithread" => Some(Target::CpuMulti(4)),
        "cpu-quant" => Some(Target::CpuQuant),
        _ => None,
    }
}

/// Numeric precision a request may pin (protocol v2 `precision` field,
/// `ClassifyOptions::precision`, CLI `--precision`). `Int8` routes the
/// batch to the quantized engine ([`Target::CpuQuant`], DESIGN.md §10);
/// `F32` (and the default, absent) keeps the request on the exact
/// engines the offload policy ranks. The policy itself never picks int8:
/// precision is a contract the caller opts into, not a latency knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Int8,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" | "float32" => Some(Precision::F32),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n5() -> DeviceProfile {
        DeviceProfile::nexus5()
    }

    #[test]
    fn static_policy_is_constant() {
        let p = OffloadPolicy::Static(Target::CpuSingle);
        for util in [0.0, 0.5, 0.9] {
            let load = LoadSnapshot { gpu_util: util, ..Default::default() };
            let t = p.decide(&n5(), ModelShape::default(), 1, load);
            assert_eq!(t, Target::CpuSingle);
        }
    }

    #[test]
    fn threshold_switches_at_cutoff() {
        let p = OffloadPolicy::Threshold { gpu_threshold: 0.6 };
        let low = LoadSnapshot { gpu_util: 0.3, ..Default::default() };
        let high = LoadSnapshot { gpu_util: 0.8, ..Default::default() };
        let lo = p.decide(&n5(), ModelShape::default(), 1, low);
        let hi = p.decide(&n5(), ModelShape::default(), 1, high);
        assert_eq!(lo, Target::Gpu(Factorization::Coarse));
        assert_eq!(hi, Target::CpuMulti(4));
    }

    #[test]
    fn cost_model_prefers_gpu_idle_cpu_loaded() {
        // The paper's Fig 7 behaviour, as a scheduler decision.
        let p = OffloadPolicy::CostModel;
        let shape = ModelShape::default();
        let idle = p.decide(&n5(), shape, 1, LoadSnapshot::default());
        assert_eq!(idle, Target::Gpu(Factorization::Coarse), "idle device: GPU wins (Fig 4)");
        let busy = LoadSnapshot { gpu_util: 0.85, cpu_util: 0.85, ..Default::default() };
        let loaded = p.decide(&n5(), shape, 1, busy);
        assert!(
            matches!(loaded, Target::CpuSingle | Target::CpuMulti(_)),
            "high load: CPU wins (Fig 7), got {loaded:?}"
        );
    }

    #[test]
    fn cost_model_monotone_region_exists() {
        // Somewhere between idle and saturated the decision flips exactly once
        // (no flapping) when CPU stays idle.
        let p = OffloadPolicy::CostModel;
        let shape = ModelShape::default();
        let mut last_gpu = true;
        let mut flips = 0;
        for i in 0..=20 {
            let u = i as f64 / 20.0;
            let load = LoadSnapshot { gpu_util: u, cpu_util: u, ..Default::default() };
            let t = p.decide(&n5(), shape, 1, load);
            let is_gpu = matches!(t, Target::Gpu(_));
            if is_gpu != last_gpu {
                flips += 1;
                last_gpu = is_gpu;
            }
        }
        assert!(flips <= 2, "decision flapped {flips} times");
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(OffloadPolicy::parse("gpu"), Some(OffloadPolicy::Static(Target::Gpu(Factorization::Coarse))));
        assert_eq!(OffloadPolicy::parse("fine"), Some(OffloadPolicy::Static(Target::Gpu(Factorization::Fine))));
        assert_eq!(OffloadPolicy::parse("cpu"), Some(OffloadPolicy::Static(Target::CpuSingle)));
        assert_eq!(OffloadPolicy::parse("cost-model"), Some(OffloadPolicy::CostModel));
        assert_eq!(OffloadPolicy::parse("threshold:0.5"), Some(OffloadPolicy::Threshold { gpu_threshold: 0.5 }));
        assert_eq!(OffloadPolicy::parse("bogus"), None);
    }

    #[test]
    fn cache_matches_uncached_decisions() {
        let mut cache = DecisionCache::new();
        let p = OffloadPolicy::CostModel;
        let shape = ModelShape::default();
        for i in 0..=50 {
            // Bucket centers: cached and uncached must agree exactly.
            let u = i as f64 / 50.0;
            let load = LoadSnapshot { gpu_util: u, cpu_util: u, ..Default::default() };
            let direct = p.decide(&n5(), shape, 1, load);
            let cached = cache.decide(&p, &n5(), shape, 1, load);
            assert_eq!(direct, cached, "util {u}");
        }
        assert!(cache.len() <= 51);
        // Second pass is pure lookup and still agrees.
        let before = cache.len();
        for i in 0..=50 {
            let u = i as f64 / 50.0;
            let load = LoadSnapshot { gpu_util: u, cpu_util: u, ..Default::default() };
            let _ = cache.decide(&p, &n5(), shape, 1, load);
        }
        assert_eq!(cache.len(), before);
    }

    #[test]
    fn inflight_pressure_saturates() {
        assert_eq!(inflight_pressure(0), 0.0);
        assert!((inflight_pressure(1) - 0.15).abs() < 1e-12);
        assert!((inflight_pressure(4) - 0.6).abs() < 1e-12);
        assert!((inflight_pressure(100) - 0.6).abs() < 1e-12, "pressure must saturate");
    }

    #[test]
    fn threshold_steers_away_from_backed_up_gpu() {
        // Same background load, different pool depth: the in-flight
        // batches alone must push the effective utilization past the
        // cutoff (the §4.5 behavior driven by real serving state).
        let p = OffloadPolicy::Threshold { gpu_threshold: 0.5 };
        let shape = ModelShape::default();
        let idle = LoadSnapshot { gpu_util: 0.2, ..Default::default() };
        let backed_up = LoadSnapshot { gpu_util: 0.2, gpu_inflight: 4, ..Default::default() };
        assert_eq!(p.decide(&n5(), shape, 1, idle), Target::Gpu(Factorization::Coarse));
        assert_eq!(p.decide(&n5(), shape, 1, backed_up), Target::CpuMulti(4));
    }

    #[test]
    fn cost_model_prices_targets_at_effective_util() {
        // The decision must equal the hand-computed argmin over the
        // candidates at their in-flight-adjusted utilizations.
        let shape = ModelShape::default();
        let load = LoadSnapshot { gpu_util: 0.2, cpu_util: 0.1, gpu_inflight: 3, cpu_inflight: 1 };
        let decided = OffloadPolicy::CostModel.decide(&n5(), shape, 2, load);
        let best = OffloadPolicy::candidates(&n5())
            .iter()
            .copied()
            .min_by_key(|&t| simulate_inference(&n5(), shape, 2, t, load.effective_util(t)))
            .unwrap();
        assert_eq!(decided, best);
    }

    #[test]
    fn cache_keys_include_inflight_depth() {
        let mut cache = DecisionCache::new();
        let p = OffloadPolicy::CostModel;
        let shape = ModelShape::default();
        let idle = LoadSnapshot::default();
        let backed_up = LoadSnapshot { gpu_inflight: 4, ..Default::default() };
        let _ = cache.decide(&p, &n5(), shape, 1, idle);
        let n = cache.len();
        let _ = cache.decide(&p, &n5(), shape, 1, backed_up);
        assert!(cache.len() > n, "distinct in-flight depths must not share a cache entry");
        // Depths beyond the saturation point share the saturated bucket.
        let deeper = LoadSnapshot { gpu_inflight: 40, ..Default::default() };
        let m = cache.len();
        let _ = cache.decide(&p, &n5(), shape, 1, deeper);
        assert_eq!(cache.len(), m, "saturated depths share one bucket");
    }

    #[test]
    fn cache_passthrough_for_static() {
        let mut cache = DecisionCache::new();
        let p = OffloadPolicy::Static(Target::CpuSingle);
        let t = cache.decide(&p, &n5(), ModelShape::default(), 1, LoadSnapshot::default());
        assert_eq!(t, Target::CpuSingle);
        assert!(cache.is_empty(), "static policies must not populate the cache");
    }

    #[test]
    fn labels() {
        assert_eq!(target_label(Target::Gpu(Factorization::Coarse)), "gpu");
        assert_eq!(target_label(Target::CpuMulti(4)), "cpu-multi");
    }

    #[test]
    fn target_labels_round_trip() {
        for t in [
            Target::Gpu(Factorization::Coarse),
            Target::Gpu(Factorization::Fine),
            Target::CpuSingle,
            Target::CpuMulti(4),
            Target::CpuQuant,
        ] {
            assert_eq!(parse_target(target_label(t)), Some(t), "{t:?}");
        }
        assert_eq!(parse_target("npu"), None);
    }

    #[test]
    fn precision_labels_round_trip() {
        for p in [Precision::F32, Precision::Int8] {
            assert_eq!(Precision::parse(p.as_str()), Some(p), "{p:?}");
        }
        assert_eq!(Precision::parse("i8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("fp16"), None);
    }

    #[test]
    fn cost_model_prices_quant_below_f32_cpu_but_never_picks_it() {
        // The simulator must price the int8 path cheaper per element
        // than the f32 CPU at every load level — and the policy must
        // still never choose it on its own: precision is a caller
        // contract, not a scheduling degree of freedom (DESIGN.md §10).
        let shape = ModelShape::default();
        for util in [0.0, 0.5, 0.9] {
            let quant = simulate_inference(&n5(), shape, 4, Target::CpuQuant, util);
            let f32cpu = simulate_inference(&n5(), shape, 4, Target::CpuSingle, util);
            assert!(quant < f32cpu, "util {util}: quant {quant} !< cpu {f32cpu}");
        }
        for i in 0..=10 {
            let u = i as f64 / 10.0;
            let load = LoadSnapshot { gpu_util: u, cpu_util: u, ..Default::default() };
            let t = OffloadPolicy::CostModel.decide(&n5(), shape, 1, load);
            assert_ne!(t, Target::CpuQuant, "policy must not silently drop precision");
        }
    }

    #[test]
    fn cost_model_flip_points_after_tail_recalibration() {
        // Pins the decision boundary the §14 tail recalibration produces
        // (F32_COMPUTE_GAIN stays the calibration frame's unit;
        // INT8_COMPUTE_GAIN re-fit 2.2 → 1.2). Under co-load the GPU
        // must win when idle, lose render-preemption-style exactly once,
        // and never win again past the flip — and at the flip the
        // decision must still equal the hand-computed argmin, so the
        // boundary location is a property of the priced curves, not of
        // tie-breaking order.
        use crate::simulator::{cpu_run, cpu_run_int8, F32_COMPUTE_GAIN, INT8_COMPUTE_GAIN};
        let p = OffloadPolicy::CostModel;
        let shape = ModelShape::default();
        let decide_at = |u: f64| {
            let load = LoadSnapshot { gpu_util: u, cpu_util: u, ..Default::default() };
            p.decide(&n5(), shape, 1, load)
        };
        let mut flip = None;
        for i in 0..=100 {
            let u = i as f64 / 100.0;
            let is_gpu = matches!(decide_at(u), Target::Gpu(_));
            match flip {
                None if !is_gpu => flip = Some(u),
                Some(f) => assert!(!is_gpu, "GPU re-won at u={u} after flipping at u={f}"),
                None => {}
            }
        }
        let flip = flip.expect("co-load sweep must leave the GPU eventually (Fig 7)");
        assert!(
            (0.2..0.95).contains(&flip),
            "flip at u={flip}: boundary drifted outside the Fig 7 regime"
        );
        let at_flip = LoadSnapshot { gpu_util: flip, cpu_util: flip, ..Default::default() };
        let best = OffloadPolicy::candidates(&n5())
            .iter()
            .copied()
            .min_by_key(|&t| simulate_inference(&n5(), shape, 1, t, at_flip.effective_util(t)))
            .unwrap();
        assert_eq!(decide_at(flip), best, "flip point must be the argmin's, not a tie-break");
        // The pricing input to that boundary: int8-over-f32 throughput
        // ratio is exactly the recalibrated constant pair.
        let f32_ns = cpu_run(&n5(), shape, 8, 1, 0.0).total_ns as f64;
        let int8_ns = cpu_run_int8(&n5(), shape, 8, 1, 0.0).total_ns as f64;
        assert!(
            (f32_ns / int8_ns - INT8_COMPUTE_GAIN / F32_COMPUTE_GAIN).abs() < 0.05,
            "priced int8/f32 ratio {} drifted from the calibrated gains",
            f32_ns / int8_ns
        );
    }

    #[test]
    fn quant_effective_util_uses_cpu_pressure() {
        // CpuQuant shares the CPU complex: its effective utilization is
        // the CPU knob plus the CPU in-flight pressure.
        let load =
            LoadSnapshot { gpu_util: 0.9, cpu_util: 0.2, cpu_inflight: 2, ..Default::default() };
        let expect = 0.2 + inflight_pressure(2);
        assert!((load.effective_util(Target::CpuQuant) - expect).abs() < 1e-12);
    }
}
