//! Dynamic batcher: group pending requests onto AOT-compiled batch sizes.
//!
//! AOT artifacts have static shapes, so the batcher can only dispatch the
//! batch sizes that were compiled (manifest `batches_for`, typically
//! {1, 2, 4, 8}). Policy:
//!
//! - dispatch when `pending ≥ max compiled batch` (take the max), or
//! - when the oldest request has waited `max_wait`, take the smallest
//!   compiled size ≥ pending and PAD with zero windows (padded outputs
//!   are discarded; padded slots are accounted in metrics).
//!
//! [`plan_batch`] is pure and exhaustively property-tested; the
//! [`BatchCollector`] adds the deadline mechanics.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// The batching decision for `pending` requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPlan {
    /// How many real requests to take.
    pub take: usize,
    /// Compiled batch size to run (take ≤ padded_to).
    pub padded_to: usize,
}

impl BatchPlan {
    pub fn padding(&self) -> usize {
        self.padded_to - self.take
    }
}

/// Choose (take, padded_to) for `pending` requests given the sorted list
/// of compiled batch sizes. Never returns take = 0 for pending > 0.
pub fn plan_batch(pending: usize, compiled: &[usize]) -> Option<BatchPlan> {
    if pending == 0 || compiled.is_empty() {
        return None;
    }
    debug_assert!(compiled.windows(2).all(|w| w[0] < w[1]), "compiled sizes must be sorted");
    let max = *compiled.last().unwrap();
    if pending >= max {
        return Some(BatchPlan { take: max, padded_to: max });
    }
    // Smallest compiled size that fits everything pending.
    let fit = *compiled.iter().find(|&&b| b >= pending).unwrap_or(&max);
    Some(BatchPlan { take: pending.min(fit), padded_to: fit })
}

/// Deadline-driven collector around [`plan_batch`].
///
/// Per-request arrival times are kept in a FIFO (dispatch takes from the
/// front), so after a partial dispatch the leftover requests keep their
/// TRUE arrival instants — the deadline clock for requests that already
/// waited must not restart from zero, or a request left over across k
/// partial dispatches could wait up to (k+1)·max_wait.
#[derive(Debug)]
pub struct BatchCollector {
    compiled: Vec<usize>,
    max_wait: Duration,
    arrivals: VecDeque<Instant>,
}

impl BatchCollector {
    pub fn new(mut compiled: Vec<usize>, max_wait: Duration) -> Self {
        compiled.sort_unstable();
        compiled.dedup();
        assert!(!compiled.is_empty(), "need at least one compiled batch size");
        Self { compiled, max_wait, arrivals: VecDeque::new() }
    }

    pub fn compiled_sizes(&self) -> &[usize] {
        &self.compiled
    }

    pub fn pending(&self) -> usize {
        self.arrivals.len()
    }

    /// A request arrived at `now`.
    pub fn push(&mut self, now: Instant) {
        self.arrivals.push_back(now);
    }

    /// Should we dispatch at `now`? Returns the plan and consumes the
    /// oldest `take` arrivals; leftovers keep their arrival instants.
    pub fn poll(&mut self, now: Instant) -> Option<BatchPlan> {
        let Some(&oldest) = self.arrivals.front() else {
            return None;
        };
        let max = *self.compiled.last().unwrap();
        let deadline_hit = now.duration_since(oldest) >= self.max_wait;
        if self.arrivals.len() >= max || deadline_hit {
            let plan = plan_batch(self.arrivals.len(), &self.compiled)?;
            self.arrivals.drain(..plan.take);
            return Some(plan);
        }
        None
    }

    /// Put polled arrivals back at the FRONT of the FIFO, in their
    /// original order. Used when a planned batch could not dispatch
    /// (every engine pool's queue was full): the requests re-enter the
    /// queue with their TRUE arrival instants, so deadline accounting is
    /// untouched and the retry fires immediately.
    pub fn restore(&mut self, arrivals: impl DoubleEndedIterator<Item = Instant>) {
        for t in arrivals.rev() {
            self.arrivals.push_front(t);
        }
    }

    /// Time until the current deadline fires (for recv_timeout), or None
    /// when idle. Driven by the oldest still-pending arrival.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.arrivals.front().map(|&t| {
            let elapsed = now.duration_since(t);
            self.max_wait.checked_sub(elapsed).unwrap_or(Duration::ZERO)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const COMPILED: &[usize] = &[1, 2, 4, 8];

    #[test]
    fn empty_and_zero() {
        assert_eq!(plan_batch(0, COMPILED), None);
        assert_eq!(plan_batch(5, &[]), None);
    }

    #[test]
    fn exact_fits() {
        for &b in COMPILED {
            let p = plan_batch(b, COMPILED).unwrap();
            assert_eq!((p.take, p.padded_to, p.padding()), (b, b, 0));
        }
    }

    #[test]
    fn overflow_takes_max() {
        let p = plan_batch(23, COMPILED).unwrap();
        assert_eq!((p.take, p.padded_to), (8, 8));
    }

    #[test]
    fn pads_up_to_next_size() {
        let p = plan_batch(3, COMPILED).unwrap();
        assert_eq!((p.take, p.padded_to, p.padding()), (3, 4, 1));
        let p = plan_batch(5, COMPILED).unwrap();
        assert_eq!((p.take, p.padded_to, p.padding()), (5, 8, 3));
    }

    #[test]
    fn property_invariants() {
        // Hand-rolled property test over random compiled sets + pendings:
        //  (1) take ≤ pending, (2) take ≤ padded_to, (3) padded_to is a
        //  compiled size, (4) padding only when pending < padded_to,
        //  (5) take > 0.
        let mut rng = Rng::new(7);
        for _ in 0..2000 {
            let mut sizes: Vec<usize> =
                (0..1 + rng.below(5) as usize).map(|_| 1 + rng.below(32) as usize).collect();
            sizes.sort_unstable();
            sizes.dedup();
            let pending = 1 + rng.below(64) as usize;
            let p = plan_batch(pending, &sizes).unwrap();
            assert!(p.take >= 1);
            assert!(p.take <= pending);
            assert!(p.take <= p.padded_to);
            assert!(sizes.contains(&p.padded_to), "{p:?} sizes {sizes:?}");
            if p.padding() > 0 {
                assert!(pending < p.padded_to);
            }
        }
    }

    #[test]
    fn property_drain_terminates_and_conserves() {
        // Repeatedly planning over a queue must consume every request
        // exactly once and terminate.
        let mut rng = Rng::new(8);
        for _ in 0..500 {
            let mut sizes: Vec<usize> =
                (0..1 + rng.below(4) as usize).map(|_| 1 + rng.below(16) as usize).collect();
            sizes.sort_unstable();
            sizes.dedup();
            let mut pending = rng.below(100) as usize;
            let total = pending;
            let mut served = 0;
            let mut iters = 0;
            while pending > 0 {
                let p = plan_batch(pending, &sizes).unwrap();
                pending -= p.take;
                served += p.take;
                iters += 1;
                assert!(iters <= total + 1, "non-terminating drain");
            }
            assert_eq!(served, total);
        }
    }

    #[test]
    fn collector_dispatches_on_full_batch() {
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![1, 2, 4, 8], Duration::from_millis(5));
        for _ in 0..8 {
            c.push(t0);
        }
        let p = c.poll(t0).unwrap();
        assert_eq!((p.take, p.padded_to), (8, 8));
        assert_eq!(c.pending(), 0);
        assert!(c.poll(t0).is_none());
    }

    #[test]
    fn collector_waits_then_fires_deadline() {
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![1, 2, 4, 8], Duration::from_millis(5));
        c.push(t0);
        c.push(t0);
        c.push(t0);
        assert!(c.poll(t0).is_none(), "below max batch, deadline not hit");
        let later = t0 + Duration::from_millis(6);
        let p = c.poll(later).unwrap();
        assert_eq!((p.take, p.padded_to), (3, 4));
    }

    #[test]
    fn collector_deadline_timer() {
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![4], Duration::from_millis(10));
        assert!(c.time_to_deadline(t0).is_none());
        c.push(t0);
        let ttd = c.time_to_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(ttd <= Duration::from_millis(6));
        let ttd2 = c.time_to_deadline(t0 + Duration::from_millis(60)).unwrap();
        assert_eq!(ttd2, Duration::ZERO);
    }

    #[test]
    fn leftovers_keep_their_original_deadline() {
        // Regression: a partial dispatch must NOT restart the leftover
        // requests' deadline clock — they already waited.
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![1, 2], Duration::from_millis(5));
        for _ in 0..3 {
            c.push(t0);
        }
        // Size-triggered partial dispatch at t0+3ms takes 2; the leftover
        // arrived at t0 and has 2ms of budget left, not a fresh 5ms.
        let p = c.poll(t0 + Duration::from_millis(3)).unwrap();
        assert_eq!(p.take, 2);
        assert_eq!(c.pending(), 1);
        assert_eq!(
            c.time_to_deadline(t0 + Duration::from_millis(3)).unwrap(),
            Duration::from_millis(2),
            "leftover deadline restarted from zero"
        );
        // At t0+5ms the leftover's original deadline fires.
        let p2 = c.poll(t0 + Duration::from_millis(5)).unwrap();
        assert_eq!((p2.take, p2.padded_to), (1, 1));
    }

    #[test]
    fn deadline_tracks_oldest_pending_not_newest() {
        // Two staggered arrivals: after the older one dispatches, the
        // deadline is the SECOND request's own arrival + max_wait.
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![1], Duration::from_millis(10));
        c.push(t0);
        c.push(t0 + Duration::from_millis(4));
        let p = c.poll(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(p.take, 1);
        // Leftover arrived at t0+4ms -> deadline t0+14ms, so 2ms left at
        // t0+12ms (the buggy reset would have reported a full 8ms).
        assert_eq!(
            c.time_to_deadline(t0 + Duration::from_millis(12)).unwrap(),
            Duration::from_millis(2)
        );
    }

    #[test]
    fn collector_leftovers_rearm() {
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![1, 2], Duration::from_millis(5));
        for _ in 0..5 {
            c.push(t0);
        }
        let p = c.poll(t0).unwrap();
        assert_eq!(p.take, 2);
        assert_eq!(c.pending(), 3);
        // Leftovers keep a deadline armed.
        assert!(c.time_to_deadline(t0).is_some());
    }

    #[test]
    #[should_panic]
    fn collector_rejects_empty_sizes() {
        BatchCollector::new(vec![], Duration::from_millis(1));
    }

    #[test]
    fn restore_preserves_order_and_deadlines() {
        // A dispatch that could not be placed puts its arrivals back at
        // the front, original order, original instants.
        let t0 = Instant::now();
        let mut c = BatchCollector::new(vec![1, 2], Duration::from_millis(5));
        let a = t0;
        let b = t0 + Duration::from_millis(1);
        let d = t0 + Duration::from_millis(2);
        c.push(a);
        c.push(b);
        c.push(d);
        let p = c.poll(t0 + Duration::from_millis(6)).unwrap();
        assert_eq!(p.take, 2, "size-2 dispatch takes the two oldest");
        assert_eq!(c.pending(), 1);
        // Pools full: put the polled pair back.
        c.restore([a, b].into_iter());
        assert_eq!(c.pending(), 3);
        // The oldest arrival is `a` again, so its (long-past) deadline
        // re-fires immediately with the same pair.
        assert_eq!(
            c.time_to_deadline(t0 + Duration::from_millis(6)).unwrap(),
            Duration::ZERO
        );
        let p2 = c.poll(t0 + Duration::from_millis(6)).unwrap();
        assert_eq!(p2.take, 2);
        // The untouched third arrival is the front again afterwards.
        assert_eq!(c.pending(), 1);
        assert_eq!(
            c.time_to_deadline(t0 + Duration::from_millis(3)).unwrap(),
            Duration::from_millis(4),
            "leftover keeps its own arrival instant"
        );
    }
}
