//! L3 coordinator — the serving control plane (the paper's system
//! contribution, recast as a first-class scheduler).
//!
//! Request path (Python never on it):
//!
//! ```text
//! client ──TCP──▶ server ──▶ Router queue ──▶ Batcher (pad to compiled B)
//!        ──▶ OffloadPolicy (reads DeviceState utilization, §4.5)
//!        ──▶ { PJRT runtime (GPU target) | native engine (CPU target) }
//!        ──▶ simulator charges mobile latency ──▶ reply + Metrics
//! ```
//!
//! - [`batcher`]  — dynamic batching onto the AOT-compiled batch sizes
//! - [`policy`]   — where to run: static, threshold, or cost-model driven
//!   (the paper's conclusion that offloading must be utilization-aware)
//! - [`device`]   — shared simulated-device state (background load knobs)
//! - [`router`]   — the serving loop tying it all together
//! - [`metrics`]  — latency histograms + counters

pub mod batcher;
pub mod device;
pub mod metrics;
pub mod policy;
pub mod router;

pub use batcher::{plan_batch, BatchCollector, BatchPlan};
pub use device::DeviceState;
pub use metrics::{Histogram, Metrics};
pub use policy::{DecisionCache, OffloadPolicy};
pub use router::{Router, RouterConfig, ServeReply, ServeRequest};
