//! L3 coordinator — the serving control plane (the paper's system
//! contribution, recast as a first-class scheduler).
//!
//! Request path (Python never on it). Since the pipelined-dispatch
//! refactor (DESIGN.md §9) the router thread is a pure SCHEDULER and
//! every engine executes on its own pool worker, so batches for
//! different targets overlap in time:
//!
//! ```text
//! client ──TCP──▶ server ──▶ Scheduler: bounded admission (max_queue,
//!        overflow ⇒ Overloaded) ──▶ Batcher (pad to compiled B, drop
//!        expired) ──▶ OffloadPolicy (DeviceState utilization + per-pool
//!        in-flight depth, §4.5)
//!        ──▶ EnginePools: Target → worker { PJRT | native 1t | native Nt }
//!            (bounded queue each; failure re-enqueues on the next pool)
//!        ──▶ pool worker: simulator charges mobile latency ──▶ reply
//! ```
//!
//! - [`batcher`]  — dynamic batching onto the AOT-compiled batch sizes
//! - [`policy`]   — where to run: static, threshold, or cost-model driven
//!   (the paper's conclusion that offloading must be utilization-aware)
//! - [`engine`]   — the [`Engine`] trait + registry + the per-engine
//!   executor pools, with generic failover (DESIGN.md §3, §9)
//! - [`health`]   — per-engine EWMA latency + consecutive-failure counts
//!   driving a three-state circuit breaker the scheduler consults before
//!   dispatch (DESIGN.md §15)
//! - [`device`]   — shared simulated-device state (background load knobs)
//! - [`router`]   — the scheduler tying it all together, built via
//!   [`RouterBuilder`]
//! - [`metrics`]  — latency histograms, counters, per-target gauges
//!
//! Streaming sessions (DESIGN.md §11) ride the same path: `open_session`
//! pins a session to a stream-capable pool, `classify_stream` chunks
//! bypass the batcher (one session's private state advance never
//! batches) and dispatch to the pinned pool with the usual failover
//! order — a cross-pool failover migrates the pin explicitly and bumps
//! `sessions_migrated`. State lives in [`crate::session::SessionStore`],
//! shared by scheduler and pool workers.

pub mod batcher;
pub mod device;
pub mod engine;
pub mod health;
pub mod metrics;
pub mod policy;
pub mod router;

pub use batcher::{plan_batch, BatchCollector, BatchPlan};
pub use device::DeviceState;
pub use engine::{
    CpuMultiEngine, CpuQuantEngine, CpuSingleEngine, Engine, EngineRegistry, PjrtEngine,
};
pub use health::{Admit, BreakerConfig, BreakerState, HealthRegistry};
pub use metrics::{Histogram, Metrics, PerTarget};
pub use policy::{
    inflight_pressure, parse_target, target_label, DecisionCache, LoadSnapshot, OffloadPolicy,
    Precision,
};
pub use router::{
    ClassifyOptions, ReplySink, Router, RouterBuilder, ServeError, ServeReply, ServeRequest,
    SessionInfo, StreamReply, StreamRequest,
};
