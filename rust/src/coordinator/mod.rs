//! L3 coordinator — the serving control plane (the paper's system
//! contribution, recast as a first-class scheduler).
//!
//! Request path (Python never on it):
//!
//! ```text
//! client ──TCP──▶ server ──▶ Router queue ──▶ Batcher (pad to compiled B)
//!        ──▶ OffloadPolicy (reads DeviceState utilization, §4.5)
//!        ──▶ EngineRegistry: Target → Engine { PJRT | native 1t | native Nt }
//!        ──▶ simulator charges mobile latency ──▶ reply + Metrics
//! ```
//!
//! - [`batcher`]  — dynamic batching onto the AOT-compiled batch sizes
//! - [`policy`]   — where to run: static, threshold, or cost-model driven
//!   (the paper's conclusion that offloading must be utilization-aware)
//! - [`engine`]   — the [`Engine`] trait + registry: one object-safe seam
//!   over every execution backend, with generic failover (DESIGN.md §3)
//! - [`device`]   — shared simulated-device state (background load knobs)
//! - [`router`]   — the serving loop tying it all together, built via
//!   [`RouterBuilder`]
//! - [`metrics`]  — latency histograms + counters

pub mod batcher;
pub mod device;
pub mod engine;
pub mod metrics;
pub mod policy;
pub mod router;

pub use batcher::{plan_batch, BatchCollector, BatchPlan};
pub use device::DeviceState;
pub use engine::{CpuMultiEngine, CpuSingleEngine, Engine, EngineRegistry, PjrtEngine};
pub use metrics::{Histogram, Metrics};
pub use policy::{parse_target, target_label, DecisionCache, OffloadPolicy};
pub use router::{
    ClassifyOptions, Router, RouterBuilder, ServeError, ServeReply, ServeRequest,
};
