//! Serving metrics: counters + log-bucketed latency histograms.
//!
//! Lock-free on the hot path (atomics only); snapshots serialize to JSON
//! for the server's `stats` command and the figure harnesses.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::json::{obj, Value};
use crate::simulator::Target;

/// Log₂-bucketed histogram over nanoseconds: bucket i covers
/// `[2^i, 2^(i+1))`, clamped to 64 buckets (≈ up to 584 years).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, ns: u64) {
        let idx = (64 - ns.max(1).leading_zeros() as usize - 1).min(63);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate percentile from bucket boundaries (upper bound of the
    /// bucket containing the p-th sample).
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return 1u64 << (i + 1).min(63);
            }
        }
        self.max_ns()
    }

    pub fn to_json(&self) -> Value {
        obj([
            ("count", Value::from(self.count())),
            ("mean_us", Value::Num(self.mean_ns() / 1e3)),
            ("p50_us", Value::Num(self.percentile_ns(50.0) as f64 / 1e3)),
            ("p95_us", Value::Num(self.percentile_ns(95.0) as f64 / 1e3)),
            ("p99_us", Value::Num(self.percentile_ns(99.0) as f64 / 1e3)),
            ("max_us", Value::Num(self.max_ns() as f64 / 1e3)),
        ])
    }
}

/// One `AtomicU64` per engine-pool kind (gpu / cpu / cpu-multi /
/// cpu-quant), addressed by [`Target`] ignoring the payload — the same
/// kind rule the engine registry uses. Used for the per-target
/// in-flight gauges the scheduler steers on (DESIGN.md §9).
#[derive(Debug, Default)]
pub struct PerTarget {
    pub gpu: AtomicU64,
    pub cpu: AtomicU64,
    pub cpu_multi: AtomicU64,
    pub cpu_quant: AtomicU64,
}

impl PerTarget {
    /// The gauge for `t`'s kind.
    pub fn slot(&self, t: Target) -> &AtomicU64 {
        match t {
            Target::Gpu(_) => &self.gpu,
            Target::CpuSingle => &self.cpu,
            Target::CpuMulti(_) => &self.cpu_multi,
            Target::CpuQuant => &self.cpu_quant,
        }
    }

    /// Sum over every kind.
    pub fn total(&self) -> u64 {
        self.gpu.load(Ordering::Relaxed)
            + self.cpu.load(Ordering::Relaxed)
            + self.cpu_multi.load(Ordering::Relaxed)
            + self.cpu_quant.load(Ordering::Relaxed)
    }

    pub fn to_json(&self) -> Value {
        obj([
            ("gpu", Value::from(self.gpu.load(Ordering::Relaxed))),
            ("cpu", Value::from(self.cpu.load(Ordering::Relaxed))),
            ("cpu_multi", Value::from(self.cpu_multi.load(Ordering::Relaxed))),
            ("cpu_quant", Value::from(self.cpu_quant.load(Ordering::Relaxed))),
        ])
    }
}

/// Top-level serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// End-to-end wall latency (enqueue → reply) on this host.
    pub wall_latency: Histogram,
    /// Simulated on-device latency (the paper's metric).
    pub sim_latency: Histogram,
    /// XLA/native compute time only.
    pub compute_latency: Histogram,
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub gpu_dispatches: AtomicU64,
    pub cpu_dispatches: AtomicU64,
    pub padded_slots: AtomicU64,
    pub errors: AtomicU64,
    /// Batches queued or executing per engine pool (gauge): incremented
    /// at dispatch, decremented when the pool finishes or forwards the
    /// batch. This is the real serving state behind
    /// `LoadSnapshot::{gpu,cpu}_inflight` (DESIGN.md §9).
    pub inflight: PerTarget,
    /// Requests sitting in the scheduler queue (gauge).
    pub queue_depth: AtomicU64,
    /// Requests rejected at admission (`RouterBuilder::max_queue`
    /// exceeded → `ServeError::Overloaded`).
    pub shed: AtomicU64,
    /// Requests dropped at dispatch because their deadline had already
    /// elapsed while they sat in the queue.
    pub expired: AtomicU64,
    /// Live streaming sessions (gauge): up on `open_session`, down on
    /// close or TTL eviction (DESIGN.md §11).
    pub sessions_open: AtomicU64,
    /// Sessions evicted by TTL — lazily at lookup or by the scheduler's
    /// periodic sweep.
    pub sessions_expired: AtomicU64,
    /// Streams whose affinity pin moved to a different engine pool
    /// because failover served a chunk elsewhere.
    pub sessions_migrated: AtomicU64,
    /// Live TCP connections (gauge): up when a server accepts, down when
    /// the handler thread or event loop drops the connection.
    pub conns_open: AtomicU64,
    /// Binary wire-v3 frames decoded off sockets (DESIGN.md §12).
    pub frames_rx: AtomicU64,
    /// Binary wire-v3 frames written to sockets.
    pub frames_tx: AtomicU64,
    /// Connections that upgraded to the binary protocol via
    /// `hello {"proto":3}`.
    pub proto_v3_negotiated: AtomicU64,
    /// Reply/refusal writes that failed; each one also kills its
    /// connection rather than silently dropping the bytes.
    pub write_failed: AtomicU64,
    /// Batches re-offered to another pool after an engine failure or a
    /// watchdog reclaim (each failover hop counts once).
    pub retries: AtomicU64,
    /// Requests whose deadline budget ran out across failover — resolved
    /// with a typed `retries_exhausted`, never a hang (DESIGN.md §15).
    pub retries_exhausted: AtomicU64,
    /// Circuit-breaker transitions into Open.
    pub breaker_open: AtomicU64,
    /// Circuit-breaker transitions into HalfOpen (probe granted).
    pub breaker_half_open: AtomicU64,
    /// Circuit-breaker transitions into Closed (recovery).
    pub breaker_closed: AtomicU64,
    /// Requests served on the int8 tier under brownout — opted in via
    /// `allow_degraded` and marked `degraded:"int8"` in the reply.
    pub degraded: AtomicU64,
    /// Dispatches reclaimed by the per-dispatch watchdog because the
    /// engine exceeded its timeout.
    pub watchdog_fired: AtomicU64,
    /// Connections closed because their write backlog stalled past the
    /// event server's stall deadline.
    pub conns_stalled: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self { wall_latency: Histogram::new(), sim_latency: Histogram::new(), compute_latency: Histogram::new(), ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn to_json(&self) -> Value {
        obj([
            // Which inner-kernel ISA this process resolved to (DESIGN.md
            // §13) — bench trajectories and latency regressions are only
            // comparable across hosts with this pinned in the snapshot.
            ("kernel_isa", Value::from(crate::kernel::active().as_str())),
            // The fused gate-tail kernel (DESIGN.md §14) — distinct from
            // kernel_isa because it pins the NUMERICS config: libm oracle
            // vs Padé approximation, which accuracy dashboards must split
            // on.
            ("kernel_tail", Value::from(crate::kernel::active().tail_label())),
            ("requests", Value::from(self.requests.load(Ordering::Relaxed))),
            ("batches", Value::from(self.batches.load(Ordering::Relaxed))),
            ("mean_batch_size", Value::Num(self.mean_batch_size())),
            ("gpu_dispatches", Value::from(self.gpu_dispatches.load(Ordering::Relaxed))),
            ("cpu_dispatches", Value::from(self.cpu_dispatches.load(Ordering::Relaxed))),
            ("padded_slots", Value::from(self.padded_slots.load(Ordering::Relaxed))),
            ("errors", Value::from(self.errors.load(Ordering::Relaxed))),
            ("shed", Value::from(self.shed.load(Ordering::Relaxed))),
            ("expired", Value::from(self.expired.load(Ordering::Relaxed))),
            ("queue_depth", Value::from(self.queue_depth.load(Ordering::Relaxed))),
            ("sessions_open", Value::from(self.sessions_open.load(Ordering::Relaxed))),
            ("sessions_expired", Value::from(self.sessions_expired.load(Ordering::Relaxed))),
            ("sessions_migrated", Value::from(self.sessions_migrated.load(Ordering::Relaxed))),
            ("conns_open", Value::from(self.conns_open.load(Ordering::Relaxed))),
            ("frames_rx", Value::from(self.frames_rx.load(Ordering::Relaxed))),
            ("frames_tx", Value::from(self.frames_tx.load(Ordering::Relaxed))),
            ("proto_v3_negotiated", Value::from(self.proto_v3_negotiated.load(Ordering::Relaxed))),
            ("write_failed", Value::from(self.write_failed.load(Ordering::Relaxed))),
            ("retries", Value::from(self.retries.load(Ordering::Relaxed))),
            ("retries_exhausted", Value::from(self.retries_exhausted.load(Ordering::Relaxed))),
            ("breaker_open", Value::from(self.breaker_open.load(Ordering::Relaxed))),
            ("breaker_half_open", Value::from(self.breaker_half_open.load(Ordering::Relaxed))),
            ("breaker_closed", Value::from(self.breaker_closed.load(Ordering::Relaxed))),
            ("degraded", Value::from(self.degraded.load(Ordering::Relaxed))),
            ("watchdog_fired", Value::from(self.watchdog_fired.load(Ordering::Relaxed))),
            ("conns_stalled", Value::from(self.conns_stalled.load(Ordering::Relaxed))),
            ("inflight", self.inflight.to_json()),
            ("wall_latency", self.wall_latency.to_json()),
            ("sim_latency", self.sim_latency.to_json()),
            ("compute_latency", self.compute_latency.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let h = Histogram::new();
        for ns in [100, 200, 400, 800, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean_ns(), (100.0 + 200.0 + 400.0 + 800.0 + 100_000.0) / 5.0);
        assert_eq!(h.max_ns(), 100_000);
    }

    #[test]
    fn percentiles_monotone_and_bounding() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i * 1000);
        }
        let p50 = h.percentile_ns(50.0);
        let p95 = h.percentile_ns(95.0);
        let p99 = h.percentile_ns(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 of 1..=1000 µs is ~500µs; bucket upper bound ≤ 1.05ms... the
        // log2 bucket containing 500_000 is [2^18, 2^19) -> upper 524288.
        assert!(p50 >= 500_000 && p50 <= 1_048_576, "{p50}");
    }

    #[test]
    fn zero_and_extreme_values_safe() {
        let h = Histogram::new();
        h.record(0); // clamped to bucket 0
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.percentile_ns(100.0) > 0);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.percentile_ns(99.0), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn metrics_json_shape() {
        let m = Metrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        m.wall_latency.record(5_000);
        m.shed.fetch_add(3, Ordering::Relaxed);
        m.expired.fetch_add(2, Ordering::Relaxed);
        m.queue_depth.store(7, Ordering::Relaxed);
        m.inflight.gpu.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        // The snapshot pins the resolved kernel ISA, and it agrees with
        // the dispatch module's label.
        assert_eq!(j.get("kernel_isa").as_str(), Some(crate::kernel::active().as_str()));
        assert_eq!(j.get("kernel_tail").as_str(), Some(crate::kernel::active().tail_label()));
        assert_eq!(j.get("requests").as_usize(), Some(10));
        assert_eq!(j.get("mean_batch_size").as_f64(), Some(2.5));
        assert_eq!(j.get("wall_latency").get("count").as_usize(), Some(1));
        assert_eq!(j.get("shed").as_usize(), Some(3));
        assert_eq!(j.get("expired").as_usize(), Some(2));
        assert_eq!(j.get("queue_depth").as_usize(), Some(7));
        assert_eq!(j.get("inflight").get("gpu").as_usize(), Some(1));
        assert_eq!(j.get("inflight").get("cpu").as_usize(), Some(0));
        // Serializes without panic and round-trips.
        let text = j.to_json();
        assert!(crate::json::parse(&text).is_ok());
    }

    #[test]
    fn session_metrics_in_json() {
        let m = Metrics::new();
        m.sessions_open.fetch_add(3, Ordering::Relaxed);
        m.sessions_expired.fetch_add(2, Ordering::Relaxed);
        m.sessions_migrated.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("sessions_open").as_usize(), Some(3));
        assert_eq!(j.get("sessions_expired").as_usize(), Some(2));
        assert_eq!(j.get("sessions_migrated").as_usize(), Some(1));
    }

    #[test]
    fn wire_metrics_in_json() {
        let m = Metrics::new();
        m.conns_open.fetch_add(5, Ordering::Relaxed);
        m.frames_rx.fetch_add(40, Ordering::Relaxed);
        m.frames_tx.fetch_add(41, Ordering::Relaxed);
        m.proto_v3_negotiated.fetch_add(3, Ordering::Relaxed);
        m.write_failed.fetch_add(2, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("conns_open").as_usize(), Some(5));
        assert_eq!(j.get("frames_rx").as_usize(), Some(40));
        assert_eq!(j.get("frames_tx").as_usize(), Some(41));
        assert_eq!(j.get("proto_v3_negotiated").as_usize(), Some(3));
        assert_eq!(j.get("write_failed").as_usize(), Some(2));
    }

    #[test]
    fn chaos_metrics_in_json() {
        let m = Metrics::new();
        m.retries.fetch_add(4, Ordering::Relaxed);
        m.retries_exhausted.fetch_add(1, Ordering::Relaxed);
        m.breaker_open.fetch_add(2, Ordering::Relaxed);
        m.breaker_half_open.fetch_add(2, Ordering::Relaxed);
        m.breaker_closed.fetch_add(1, Ordering::Relaxed);
        m.degraded.fetch_add(5, Ordering::Relaxed);
        m.watchdog_fired.fetch_add(1, Ordering::Relaxed);
        m.conns_stalled.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("retries").as_usize(), Some(4));
        assert_eq!(j.get("retries_exhausted").as_usize(), Some(1));
        assert_eq!(j.get("breaker_open").as_usize(), Some(2));
        assert_eq!(j.get("breaker_half_open").as_usize(), Some(2));
        assert_eq!(j.get("breaker_closed").as_usize(), Some(1));
        assert_eq!(j.get("degraded").as_usize(), Some(5));
        assert_eq!(j.get("watchdog_fired").as_usize(), Some(1));
        assert_eq!(j.get("conns_stalled").as_usize(), Some(1));
    }

    #[test]
    fn snapshot_schema_keys_are_pinned() {
        // The snapshot is the wire contract for `stats` consumers —
        // adding a counter must update this list deliberately. Keys are
        // sorted because `obj` stores a BTreeMap.
        let j = Metrics::new().to_json();
        let keys: Vec<&str> =
            j.as_obj().expect("snapshot is an object").keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            [
                "batches",
                "breaker_closed",
                "breaker_half_open",
                "breaker_open",
                "compute_latency",
                "conns_open",
                "conns_stalled",
                "cpu_dispatches",
                "degraded",
                "errors",
                "expired",
                "frames_rx",
                "frames_tx",
                "gpu_dispatches",
                "inflight",
                "kernel_isa",
                "kernel_tail",
                "mean_batch_size",
                "padded_slots",
                "proto_v3_negotiated",
                "queue_depth",
                "requests",
                "retries",
                "retries_exhausted",
                "sessions_expired",
                "sessions_migrated",
                "sessions_open",
                "shed",
                "sim_latency",
                "wall_latency",
                "watchdog_fired",
                "write_failed",
            ]
        );
    }

    #[test]
    fn per_target_slots_by_kind() {
        use crate::simulator::Factorization;
        let g = PerTarget::default();
        g.slot(Target::Gpu(Factorization::Fine)).fetch_add(2, Ordering::Relaxed);
        g.slot(Target::Gpu(Factorization::Coarse)).fetch_add(1, Ordering::Relaxed);
        g.slot(Target::CpuMulti(4)).fetch_add(1, Ordering::Relaxed);
        g.slot(Target::CpuQuant).fetch_add(2, Ordering::Relaxed);
        // Payload is ignored: both factorizations land on the one gpu gauge.
        assert_eq!(g.gpu.load(Ordering::Relaxed), 3);
        assert_eq!(g.cpu.load(Ordering::Relaxed), 0);
        assert_eq!(g.cpu_multi.load(Ordering::Relaxed), 1);
        assert_eq!(g.cpu_quant.load(Ordering::Relaxed), 2);
        assert_eq!(g.total(), 6);
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.record((t * 1000 + i) as u64 + 1);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
