//! Deterministic fault injection for engines (DESIGN.md §15).
//!
//! A [`FaultPlan`] is a seedable schedule of engine misbehaviour parsed
//! from a compact grammar (CLI `--fault-plan` / env `MOBIRNN_FAULT_PLAN`):
//!
//! ```text
//! plan    := entry (';' entry)*
//! entry   := label ':' setting (',' setting)*
//! setting := key '=' value
//! ```
//!
//! `label` matches [`Engine::label`] (`gpu`, `cpu`, `cpu-multi`,
//! `cpu-quant`); `all` / `*` match every engine, and `pjrt` is accepted
//! as an alias for `gpu`. Supported keys:
//!
//! | key           | meaning                                                |
//! |---------------|--------------------------------------------------------|
//! | `fail_rate`   | probability in `[0,1]` that a call returns an error    |
//! | `fail_first`  | the first N calls fail, later calls are healthy        |
//! | `fail_after`  | calls beyond the first N fail forever (pool death)     |
//! | `latency_ms`  | injected sleep; `200@p25` sleeps on 25% of calls       |
//! | `hang_after`  | calls beyond the first N hang (bounded by `hang_ms`)   |
//! | `hang_ms`     | how long an injected hang sleeps before erroring       |
//! | `corrupt_rate`| probability that outputs are NaN-poisoned              |
//! | `seed`        | RNG seed (mixed with the engine label)                 |
//!
//! Example: `pjrt:fail_rate=0.3,latency_ms=200@p50,hang_after=100`.
//!
//! Faults are injected by [`FaultyEngine`], a transparent [`Engine`]
//! wrapper. Randomness comes from a per-engine seeded [`Rng`], and each
//! pool runs a single worker thread, so a given (plan, traffic order)
//! replays the same fault schedule — chaos tests assert exact breaker
//! transitions against it. Injected hangs sleep `hang_ms` and then
//! return an error, so they are bounded even without the dispatch
//! watchdog; the watchdog exists for engines that wedge for real.
//!
//! [`StubEngine`] is a tiny deterministic engine (always predicts class
//! 1) exported for integration tests and benches, which cannot reach the
//! crate's `#[cfg(test)]` fixtures.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::engine::Engine;
use crate::lstm::StreamState;
use crate::simulator::Target;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};

/// Fault settings for one engine, parsed from one plan entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Probability in `[0, 1]` that a call fails with a typed error.
    pub fail_rate: f64,
    /// The first `fail_first` calls fail; later calls are healthy.
    pub fail_first: u64,
    /// Calls after the first `fail_after` fail forever (permanent death).
    pub fail_after: Option<u64>,
    /// Injected latency per affected call.
    pub latency_ms: u64,
    /// Probability in `[0, 1]` that `latency_ms` applies to a call.
    pub latency_prob: f64,
    /// Calls after the first `hang_after` hang for `hang_ms`, then fail.
    pub hang_after: Option<u64>,
    /// Duration of an injected hang before it resolves into an error.
    pub hang_ms: u64,
    /// Probability in `[0, 1]` that outputs are NaN-poisoned.
    pub corrupt_rate: f64,
    /// Seed for the per-engine fault RNG.
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            fail_rate: 0.0,
            fail_first: 0,
            fail_after: None,
            latency_ms: 0,
            latency_prob: 1.0,
            hang_after: None,
            hang_ms: 5_000,
            corrupt_rate: 0.0,
            seed: 0,
        }
    }
}

impl FaultSpec {
    fn parse_settings(settings: &str) -> Result<Self> {
        let mut spec = FaultSpec::default();
        for setting in settings.split(',') {
            let setting = setting.trim();
            if setting.is_empty() {
                continue;
            }
            let (key, value) = setting
                .split_once('=')
                .ok_or_else(|| anyhow!("fault setting {setting:?} is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "fail_rate" => spec.fail_rate = parse_rate(key, value)?,
                "fail_first" => spec.fail_first = parse_count(key, value)?,
                "fail_after" => spec.fail_after = Some(parse_count(key, value)?),
                "latency_ms" => {
                    // `200@p25` = 200ms on 25% of calls; bare `200` = every call.
                    let (ms, prob) = match value.split_once('@') {
                        Some((ms, pct)) => {
                            let pct = pct
                                .strip_prefix('p')
                                .ok_or_else(|| anyhow!("latency percentile {pct:?} must be pNN"))?;
                            let pct: f64 = pct
                                .parse()
                                .with_context(|| format!("latency percentile {pct:?}"))?;
                            if !(0.0..=100.0).contains(&pct) {
                                bail!("latency percentile {pct} out of [0, 100]");
                            }
                            (ms, pct / 100.0)
                        }
                        None => (value, 1.0),
                    };
                    spec.latency_ms = parse_count(key, ms)?;
                    spec.latency_prob = prob;
                }
                "hang_after" => spec.hang_after = Some(parse_count(key, value)?),
                "hang_ms" => spec.hang_ms = parse_count(key, value)?,
                "corrupt_rate" => spec.corrupt_rate = parse_rate(key, value)?,
                "seed" => spec.seed = parse_count(key, value)?,
                _ => bail!("unknown fault key {key:?}"),
            }
        }
        Ok(spec)
    }

    fn is_noop(&self) -> bool {
        *self == FaultSpec { seed: self.seed, ..FaultSpec::default() }
    }
}

fn parse_rate(key: &str, value: &str) -> Result<f64> {
    let rate: f64 = value.parse().with_context(|| format!("fault {key}={value:?}"))?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("fault {key}={rate} out of [0, 1]");
    }
    Ok(rate)
}

fn parse_count(key: &str, value: &str) -> Result<u64> {
    value.parse().with_context(|| format!("fault {key}={value:?}"))
}

/// A parsed fault plan: per-engine-label [`FaultSpec`]s.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    entries: Vec<(String, FaultSpec)>,
}

impl FaultPlan {
    /// Parse the plan grammar (see module docs). Empty input is an empty plan.
    pub fn parse(plan: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for entry in plan.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (label, settings) = entry
                .split_once(':')
                .ok_or_else(|| anyhow!("fault entry {entry:?} is not label:settings"))?;
            let label = label.trim();
            if label.is_empty() {
                bail!("fault entry {entry:?} has an empty engine label");
            }
            entries.push((label.to_string(), FaultSpec::parse_settings(settings)?));
        }
        Ok(FaultPlan { entries })
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The spec applying to an engine label (first matching entry wins).
    pub fn spec_for(&self, label: &str) -> Option<FaultSpec> {
        self.entries
            .iter()
            .find(|(pat, _)| {
                pat == label
                    || pat == "all"
                    || pat == "*"
                    || (pat == "pjrt" && label == "gpu")
            })
            .map(|(_, spec)| *spec)
    }

    /// Wrap an engine in a [`FaultyEngine`] when the plan targets it;
    /// engines the plan does not mention pass through untouched.
    pub fn wrap(&self, engine: Box<dyn Engine>) -> Box<dyn Engine> {
        match self.spec_for(engine.label()) {
            Some(spec) if !spec.is_noop() => Box::new(FaultyEngine::new(engine, spec)),
            _ => engine,
        }
    }
}

/// Mixes the engine label into the seed so two engines covered by one
/// `all:` entry still draw independent fault sequences.
fn label_seed(seed: u64, label: &str) -> u64 {
    let mut h = seed ^ 0x9e37_79b9_7f4a_7c15;
    for b in label.bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// An [`Engine`] wrapper injecting the faults described by a [`FaultSpec`].
///
/// Call accounting is shared between `infer` and `infer_stream`: the
/// N-th call to either is call N of the schedule.
pub struct FaultyEngine {
    inner: Box<dyn Engine>,
    spec: FaultSpec,
    calls: AtomicU64,
    rng: Mutex<Rng>,
}

enum Injected {
    /// Run the wrapped engine; optionally NaN-poison its output.
    Pass { corrupt: bool },
    /// Fail without touching the wrapped engine (state stays clean).
    Fail(anyhow::Error),
}

impl FaultyEngine {
    pub fn new(inner: Box<dyn Engine>, spec: FaultSpec) -> Self {
        let seed = label_seed(spec.seed, inner.label());
        FaultyEngine { inner, spec, calls: AtomicU64::new(0), rng: Mutex::new(Rng::new(seed)) }
    }

    /// Decide this call's fate. Draw order is fixed (latency, failure,
    /// corruption) so schedules replay deterministically.
    fn inject(&self) -> Injected {
        let call = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        let spec = &self.spec;
        let label = self.inner.label();
        let (latency_roll, fail_roll, corrupt_roll) = {
            let mut rng = self.rng.lock().unwrap();
            (rng.next_f64(), rng.next_f64(), rng.next_f64())
        };
        if spec.latency_ms > 0 && latency_roll < spec.latency_prob {
            std::thread::sleep(Duration::from_millis(spec.latency_ms));
        }
        if let Some(after) = spec.hang_after {
            if call > after {
                // A bounded stand-in for a wedged engine: sleep long enough
                // for the dispatch watchdog to fire, then surface an error
                // so the worker thread is reclaimed.
                std::thread::sleep(Duration::from_millis(spec.hang_ms));
                return Injected::Fail(anyhow!("injected hang on {label} call {call}"));
            }
        }
        if call <= spec.fail_first {
            return Injected::Fail(anyhow!("injected failure on {label} call {call} (fail_first)"));
        }
        if let Some(after) = spec.fail_after {
            if call > after {
                return Injected::Fail(anyhow!(
                    "injected failure on {label} call {call} (fail_after)"
                ));
            }
        }
        if fail_roll < spec.fail_rate {
            return Injected::Fail(anyhow!("injected failure on {label} call {call}"));
        }
        Injected::Pass { corrupt: corrupt_roll < spec.corrupt_rate }
    }
}

impl Engine for FaultyEngine {
    fn target(&self) -> Target {
        self.inner.target()
    }

    fn supported_batches(&self) -> &[usize] {
        self.inner.supported_batches()
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        match self.inject() {
            Injected::Fail(err) => Err(err),
            Injected::Pass { corrupt } => {
                let mut y = self.inner.infer(x)?;
                if corrupt {
                    for v in y.data_mut().iter_mut() {
                        *v = f32::NAN;
                    }
                }
                Ok(y)
            }
        }
    }

    fn infer_stream(
        &self,
        frames: &[f32],
        steps: usize,
        state: &mut StreamState,
    ) -> Result<Vec<f32>> {
        match self.inject() {
            Injected::Fail(err) => Err(err),
            Injected::Pass { corrupt } => {
                let mut y = self.inner.infer_stream(frames, steps, state)?;
                if corrupt {
                    for v in y.iter_mut() {
                        *v = f32::NAN;
                    }
                }
                Ok(y)
            }
        }
    }

    fn supports_streaming(&self) -> bool {
        self.inner.supports_streaming()
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }
}

/// A deterministic engine for integration tests and benches: every row
/// scores class 1. Streams are supported and count calls like `infer`.
///
/// The crate's richer `#[cfg(test)]` fixtures are not visible to
/// `tests/*.rs` or benches, so chaos tooling uses this instead.
pub struct StubEngine {
    /// Target reported to the scheduler.
    pub target: Target,
    /// Logit width; must match the served `ModelShape::num_classes`.
    pub num_classes: usize,
    /// Calls observed (either entry point).
    pub calls: AtomicU64,
}

impl StubEngine {
    pub fn new(target: Target, num_classes: usize) -> Self {
        StubEngine { target, num_classes, calls: AtomicU64::new(0) }
    }

    fn row(&self) -> Vec<f32> {
        let mut row = vec![0.0; self.num_classes];
        if self.num_classes > 1 {
            row[1] = 1.0;
        }
        row
    }
}

impl Engine for StubEngine {
    fn target(&self) -> Target {
        self.target
    }

    fn supported_batches(&self) -> &[usize] {
        &[1, 2, 4, 8]
    }

    fn infer(&self, x: &Tensor) -> Result<Tensor> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let batch = x.shape()[0];
        let mut out = Vec::with_capacity(batch * self.num_classes);
        for _ in 0..batch {
            out.extend_from_slice(&self.row());
        }
        Ok(Tensor::new(vec![batch, self.num_classes], out))
    }

    fn infer_stream(
        &self,
        _frames: &[f32],
        steps: usize,
        _state: &mut StreamState,
    ) -> Result<Vec<f32>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::with_capacity(steps * self.num_classes);
        for _ in 0..steps {
            out.extend_from_slice(&self.row());
        }
        Ok(out)
    }

    fn supports_streaming(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_round_trips_the_issue_example() {
        let plan = FaultPlan::parse("pjrt:fail_rate=0.3,latency_ms=200@p50,hang_after=100")
            .expect("plan parses");
        let spec = plan.spec_for("gpu").expect("pjrt aliases gpu");
        assert_eq!(spec.fail_rate, 0.3);
        assert_eq!(spec.latency_ms, 200);
        assert_eq!(spec.latency_prob, 0.5);
        assert_eq!(spec.hang_after, Some(100));
        assert!(plan.spec_for("cpu").is_none());
    }

    #[test]
    fn wildcard_and_multi_entry_plans_parse() {
        let plan = FaultPlan::parse("all:seed=7,fail_rate=0.1;cpu:fail_after=3,hang_ms=250")
            .expect("plan parses");
        // First matching entry wins: `all` shadows the later `cpu` entry.
        assert_eq!(plan.spec_for("cpu").unwrap().fail_rate, 0.1);
        assert_eq!(plan.spec_for("gpu").unwrap().seed, 7);

        let plan = FaultPlan::parse("cpu:fail_after=3;*:latency_ms=5").expect("plan parses");
        assert_eq!(plan.spec_for("cpu").unwrap().fail_after, Some(3));
        assert_eq!(plan.spec_for("cpu-multi").unwrap().latency_ms, 5);
        assert!(FaultPlan::parse("").expect("empty plan").is_empty());
    }

    #[test]
    fn malformed_plans_are_typed_errors() {
        for bad in [
            "cpu",                    // no settings
            "cpu:fail_rate",          // no value
            "cpu:fail_rate=2.0",      // out of range
            "cpu:latency_ms=5@x50",   // bad percentile tag
            "cpu:latency_ms=5@p150",  // percentile out of range
            "cpu:bogus_key=1",        // unknown key
            ":fail_rate=0.5",         // empty label
            "cpu:fail_first=-1",      // negative count
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn fail_first_and_fail_after_follow_call_count() {
        let spec = FaultSpec { fail_first: 2, ..FaultSpec::default() };
        let engine = FaultyEngine::new(Box::new(StubEngine::new(Target::CpuSingle, 6)), spec);
        let x = Tensor::new(vec![1, 10, 3], vec![0.0; 30]);
        assert!(engine.infer(&x).is_err());
        assert!(engine.infer(&x).is_err());
        assert!(engine.infer(&x).is_ok());

        let spec = FaultSpec { fail_after: Some(2), ..FaultSpec::default() };
        let engine = FaultyEngine::new(Box::new(StubEngine::new(Target::CpuSingle, 6)), spec);
        assert!(engine.infer(&x).is_ok());
        assert!(engine.infer(&x).is_ok());
        assert!(engine.infer(&x).is_err());
        assert!(engine.infer(&x).is_err());
    }

    #[test]
    fn fail_rate_schedule_is_deterministic_for_a_seed() {
        let x = Tensor::new(vec![1, 10, 3], vec![0.0; 30]);
        let outcomes = |seed: u64| -> Vec<bool> {
            let spec = FaultSpec { fail_rate: 0.5, seed, ..FaultSpec::default() };
            let engine =
                FaultyEngine::new(Box::new(StubEngine::new(Target::CpuSingle, 6)), spec);
            (0..64).map(|_| engine.infer(&x).is_ok()).collect()
        };
        assert_eq!(outcomes(42), outcomes(42), "same seed must replay");
        assert_ne!(outcomes(42), outcomes(43), "different seeds must diverge");
        let oks = outcomes(42).iter().filter(|ok| **ok).count();
        assert!((16..=48).contains(&oks), "rate 0.5 of 64 draws, got {oks} ok");
    }

    #[test]
    fn corrupt_mode_poisons_outputs_with_nan() {
        let spec = FaultSpec { corrupt_rate: 1.0, ..FaultSpec::default() };
        let engine = FaultyEngine::new(Box::new(StubEngine::new(Target::CpuSingle, 6)), spec);
        let x = Tensor::new(vec![1, 10, 3], vec![0.0; 30]);
        let y = engine.infer(&x).expect("corruption is not failure");
        assert!(y.data().iter().all(|v| v.is_nan()));
    }

    #[test]
    fn hang_mode_is_bounded_and_surfaces_an_error() {
        let spec = FaultSpec { hang_after: Some(0), hang_ms: 20, ..FaultSpec::default() };
        let engine = FaultyEngine::new(Box::new(StubEngine::new(Target::CpuSingle, 6)), spec);
        let x = Tensor::new(vec![1, 10, 3], vec![0.0; 30]);
        let t0 = std::time::Instant::now();
        let err = engine.infer(&x).expect_err("hang resolves into an error");
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert!(format!("{err:#}").contains("injected hang"));
    }

    #[test]
    fn unmentioned_engines_pass_through_unwrapped() {
        let plan = FaultPlan::parse("gpu:fail_rate=1.0").unwrap();
        let wrapped = plan.wrap(Box::new(StubEngine::new(Target::CpuSingle, 6)));
        let x = Tensor::new(vec![1, 10, 3], vec![0.0; 30]);
        assert!(wrapped.infer(&x).is_ok(), "cpu engine is not in the plan");
    }
}
