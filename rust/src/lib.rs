//! # MobiRNN — mobile-GPU-aware LSTM serving (EMDL'17 reproduction)
//!
//! This crate is Layer 3 of the three-layer stack described in DESIGN.md:
//! a Rust serving coordinator that executes AOT-compiled JAX/Pallas LSTM
//! artifacts via PJRT, decides *where* each inference should run (GPU vs
//! CPU — the paper's central question) using a discrete-event mobile-SoC
//! simulator as the hardware substrate, and regenerates every figure in
//! the paper's evaluation.
//!
//! Module map (see DESIGN.md §4 for the full systems inventory):
//!
//! - [`tensor`]     — minimal dense f32 tensor used across the crate
//! - [`config`]     — model/variant/manifest configuration
//! - [`json`]       — in-crate JSON tree + the `ToValue`/`FromValue`
//!   codec traits the wire protocol is typed through
//! - [`kernel`]     — runtime SIMD dispatch (scalar/AVX2/NEON) for the
//!   f32 and int8 inner GEMM kernels (DESIGN.md §13)
//! - [`lstm`]       — native Rust LSTM forward pass (CPU engines) + MRNW weights
//! - [`har`]        — synthetic HAR dataset substrate (MRNH loader + generator)
//! - [`simulator`]  — DES mobile-SoC simulator (GPU slots, launch overhead,
//!   shared bandwidth, background load; Fine vs Coarse factorization)
//! - [`runtime`]    — PJRT runtime: HLO-text artifacts -> compile -> execute
//! - [`coordinator`]— `RouterBuilder`/router, dynamic batcher, the `Engine`
//!   registry over all execution backends, utilization-aware offload policy,
//!   per-engine health tracking + circuit breakers (DESIGN.md §15)
//! - [`faults`]     — deterministic, seedable fault-injection plans that
//!   wrap any `Engine` for chaos testing (`--fault-plan`)
//! - [`server`]     — std::net TCP front-end speaking the typed JSON-lines
//!   protocol v2 (`Request`/`Response` enums)
//! - [`session`]    — sharded session store for streaming stateful
//!   inference (persistent per-client h/c state, TTL eviction)
//! - [`figures`]    — harnesses that regenerate paper Figs 2–7
//! - [`util`]       — deterministic RNG + stats helpers

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod faults;
pub mod figures;
pub mod har;
pub mod json;
pub mod kernel;
pub mod lstm;
pub mod runtime;
pub mod server;
pub mod session;
pub mod simulator;
pub mod tensor;
pub mod util;

pub use config::{Manifest, ModelShape, VariantInfo};
pub use tensor::Tensor;
