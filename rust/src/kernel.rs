//! Runtime kernel dispatch: pick the widest SIMD inner kernels the host
//! actually has, once, at startup.
//!
//! The native hot path bottoms out in four inner kernels — the f32
//! GEMM/GEMV pair (`tensor::matmul_into` / `tensor::gemv_into`), the
//! int8 GEMM (`lstm::quant::quant_matmul_into`), and the fused LSTM gate
//! tail (`lstm::tail::lstm_tail`, the point-wise `(i,g,f,o) → c', h'`
//! update). Each has three implementations:
//!
//! - **scalar** — the original quad-blocked kernels, kept verbatim (plus
//!   the K-remainder bugfix) as the parity oracle and the fallback for
//!   hosts without the detected features;
//! - **AVX2** (x86_64, requires `avx2` + `fma`) — 8-lane f32 with fused
//!   multiply-add, 8-lane widening i8×i8→i32;
//! - **NEON** (aarch64, baseline) — 4-lane f32 `vfmaq`, widening
//!   `vmlal_s16` int8.
//!
//! Selection happens ONCE per process (first call to [`dispatch`] /
//! [`active`]), via `std::arch` runtime feature detection, and is cached
//! in an atomic so the hot path pays one relaxed load + an indirect call.
//! The scalar path stays reachable in production two ways: the
//! `MOBIRNN_FORCE_SCALAR` environment variable (any value but `0`/empty)
//! and [`force_scalar`] (the `--force-scalar` CLI flag) — CI runs the
//! whole tier-1 suite a second time under the env var so the fallback
//! cannot rot.
//!
//! Numerics contract (DESIGN.md §13–§14): the int8 GEMM is **bit-exact**
//! across ISAs (integer adds are associative). The f32 SIMD GEMMs use
//! fused multiply-adds and therefore differ from scalar within a
//! documented absolute bound; within ONE ISA, `matmul_into` remains
//! bit-for-bit equal to m independent `gemv_into` calls (every M-block
//! path performs the identical per-element fma chain), so the
//! batched-vs-per-window and streaming parity guarantees hold unchanged.
//! The tail kernel has a two-sided contract of its own: the scalar entry
//! is the exact libm oracle, while the AVX2/NEON entries run a clamped
//! Padé (5,4) approximation within `lstm::tail::TAIL_{C,H}_MAX_ABS_ERR`
//! of libm — and, being built without FMA, are bit-identical to the
//! scalar Padé helpers lane-for-lane, so per-row chunking (PlanPool,
//! streaming) cannot perturb results within one ISA.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which inner-kernel implementation the process is running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable scalar kernels — the parity oracle and universal fallback.
    Scalar,
    /// x86_64 AVX2 + FMA (runtime-detected).
    Avx2,
    /// aarch64 NEON (architectural baseline).
    Neon,
}

impl KernelIsa {
    /// Stable lowercase label — logged at startup, emitted in the metrics
    /// snapshot (`kernel_isa`) and in `BENCH_hotpath.json` machine info.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Neon => "neon",
        }
    }

    /// Stable label for the tail kernel this ISA selects — logged in the
    /// startup `kernels:` line and emitted as `kernel_tail` in the
    /// metrics snapshot. Distinct from [`Self::as_str`] because the tail
    /// contract is numeric, not just a lane width: scalar means the
    /// exact libm oracle, the SIMD ISAs mean the Padé approximation.
    pub fn tail_label(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "libm-scalar",
            KernelIsa::Avx2 => "pade-avx2",
            KernelIsa::Neon => "pade-neon",
        }
    }
}

/// The resolved kernel table: one function pointer per inner kernel.
/// `quant_matmul` takes the packed image as raw slices
/// (`acc, a, w_data, m, k_padded, n`) so the table stays free of any
/// `lstm`-layer types.
pub struct KernelDispatch {
    pub isa: KernelIsa,
    pub matmul_f32: fn(&mut [f32], &[f32], &[f32], usize, usize, usize),
    pub gemv_f32: fn(&mut [f32], &[f32], &[f32]),
    pub quant_matmul: fn(&mut [i32], &[i8], &[i8], usize, usize, usize),
    /// Fused LSTM gate tail: `(gates [rows,4H], h [rows,H], c [rows,H],
    /// rows, hid)`; overwrites `h`/`c` in place (DESIGN.md §14).
    pub lstm_tail_f32: fn(&[f32], &mut [f32], &mut [f32], usize, usize),
}

static SCALAR: KernelDispatch = KernelDispatch {
    isa: KernelIsa::Scalar,
    matmul_f32: crate::tensor::matmul_into_scalar,
    gemv_f32: crate::tensor::gemv_into_scalar,
    quant_matmul: crate::lstm::quant::quant_matmul_scalar,
    lstm_tail_f32: crate::lstm::tail::lstm_tail_scalar,
};

#[cfg(target_arch = "x86_64")]
static AVX2: KernelDispatch = KernelDispatch {
    isa: KernelIsa::Avx2,
    matmul_f32: crate::tensor::simd::matmul_into_avx2,
    gemv_f32: crate::tensor::simd::gemv_into_avx2,
    quant_matmul: crate::lstm::quant::simd::quant_matmul_avx2,
    lstm_tail_f32: crate::lstm::tail::simd::lstm_tail_avx2,
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelDispatch = KernelDispatch {
    isa: KernelIsa::Neon,
    matmul_f32: crate::tensor::simd::matmul_into_neon,
    gemv_f32: crate::tensor::simd::gemv_into_neon,
    quant_matmul: crate::lstm::quant::simd::quant_matmul_neon,
    lstm_tail_f32: crate::lstm::tail::simd::lstm_tail_neon,
};

/// 0 = undecided; the rest mirror [`KernelIsa`]. A relaxed CAS publishes
/// the first detection — the race is benign because `detect()` is a pure
/// function of the host (and the env var, read once per call).
const TAG_UNSET: u8 = 0;
const TAG_SCALAR: u8 = 1;
const TAG_AVX2: u8 = 2;
const TAG_NEON: u8 = 3;

static ACTIVE: AtomicU8 = AtomicU8::new(TAG_UNSET);

fn scalar_forced_by_env() -> bool {
    std::env::var_os("MOBIRNN_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0")
}

fn detect() -> u8 {
    if scalar_forced_by_env() {
        return TAG_SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return TAG_AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return TAG_NEON;
    }
    #[allow(unreachable_code)]
    TAG_SCALAR
}

fn active_tag() -> u8 {
    let tag = ACTIVE.load(Ordering::Relaxed);
    if tag != TAG_UNSET {
        return tag;
    }
    let detected = detect();
    // First writer wins; a concurrent force_scalar() store also wins —
    // either way the subsequent load is the settled answer.
    let _ = ACTIVE.compare_exchange(TAG_UNSET, detected, Ordering::Relaxed, Ordering::Relaxed);
    ACTIVE.load(Ordering::Relaxed)
}

/// The ISA the dispatch table is (or will be) resolved to.
pub fn active() -> KernelIsa {
    match active_tag() {
        TAG_AVX2 => KernelIsa::Avx2,
        TAG_NEON => KernelIsa::Neon,
        _ => KernelIsa::Scalar,
    }
}

/// Pin the process to the scalar kernels (the `--force-scalar` CLI
/// path) — GEMMs AND the gate tail, which thereby becomes the exact
/// libm oracle. Effective even after a SIMD table was already
/// selected — in-flight calls finish on the old table; every later
/// dispatch is scalar.
pub fn force_scalar() {
    ACTIVE.store(TAG_SCALAR, Ordering::Relaxed);
}

/// The resolved kernel table for this process.
pub fn dispatch() -> &'static KernelDispatch {
    match active_tag() {
        #[cfg(target_arch = "x86_64")]
        TAG_AVX2 => &AVX2,
        #[cfg(target_arch = "aarch64")]
        TAG_NEON => &NEON,
        _ => &SCALAR,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_stable() {
        assert_eq!(KernelIsa::Scalar.as_str(), "scalar");
        assert_eq!(KernelIsa::Avx2.as_str(), "avx2");
        assert_eq!(KernelIsa::Neon.as_str(), "neon");
        assert_eq!(KernelIsa::Scalar.tail_label(), "libm-scalar");
        assert_eq!(KernelIsa::Avx2.tail_label(), "pade-avx2");
        assert_eq!(KernelIsa::Neon.tail_label(), "pade-neon");
    }

    #[test]
    fn dispatch_table_matches_active_isa() {
        // Whatever was detected (host- and env-dependent), the table and
        // the reported ISA must agree — the observability contract.
        assert_eq!(dispatch().isa, active());
    }

    #[test]
    fn detected_isa_is_possible_on_this_arch() {
        let isa = active();
        #[cfg(target_arch = "x86_64")]
        assert_ne!(isa, KernelIsa::Neon);
        #[cfg(target_arch = "aarch64")]
        assert_ne!(isa, KernelIsa::Avx2);
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert_eq!(isa, KernelIsa::Scalar);
    }

    // force_scalar() is process-global and would blind the SIMD↔scalar
    // parity tests running in sibling threads, so it is exercised by the
    // scalar-forced CI lane (MOBIRNN_FORCE_SCALAR=1) and the CLI flag
    // test, not flipped here.
}
