//! Deterministic RNG and small statistics helpers.
//!
//! The crate avoids a `rand` dependency: everything that needs randomness
//! (workload generation, request arrival jitter, background-load traces)
//! uses this SplitMix64, so every figure and test is reproducible from a
//! single `u64` seed.

/// SplitMix64 PRNG (Steele et al., "Fast splittable pseudorandom number
/// generators"). Tiny state, passes BigCrush, perfect for simulation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded generation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential inter-arrival with the given rate (events per unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.next_f64().max(1e-12).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Streaming summary statistics over f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile by nearest-rank on a sorted copy (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Format nanoseconds human-readably (figures output).
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seed_sensitivity() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn stats_empty_safe() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.00 s");
    }
}
