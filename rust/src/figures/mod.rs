//! Figure harnesses: regenerate every table/figure in the paper's
//! evaluation (DESIGN.md §5 experiment index).
//!
//! Each `figN()` returns structured rows (asserted by integration tests
//! and serialized into EXPERIMENTS.md); `print_*` renders the table the
//! way the paper's figure reads. All series come from the calibrated
//! device simulator — the substitution for the Nexus 5/6P testbed — while
//! the *numerics* those latencies describe run for real through PJRT or
//! the native engine (see coordinator::router).

pub mod figs;

pub use figs::*;
