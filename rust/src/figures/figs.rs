//! The per-figure series generators.

use crate::config::ModelShape;
use crate::simulator::{
    build_trace_with_slots, gpu_run, simulate_inference, DeviceProfile, Factorization,
    LoadLevel, Target, TraceOpts,
};
use crate::util::Stats;

/// The paper's "100 randomly selected test cases" (§4.1).
pub const TEST_CASES: usize = 100;

/// Model sweep used by Figs 3/5/6: (layers, hidden).
pub const COMPLEXITY_SWEEP: [(usize, usize); 6] =
    [(1, 32), (2, 32), (3, 32), (2, 64), (2, 128), (2, 256)];

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

// ---------------------------------------------------------------- Fig 2

/// Fig 2: the factorization contrast on the paper's own example — a
/// 32-dim input vector times a 32×120 weight matrix.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub strategy: &'static str,
    pub work_units: usize,
    pub launches: usize,
    pub products_per_unit: usize,
    pub sim_us: f64,
}

pub fn fig2(profile: &DeviceProfile) -> Vec<Fig2Row> {
    // 120 output columns (the paper's 32x120), input dim 2+30 = 32.
    let shape = ModelShape {
        num_layers: 1,
        hidden: 30,
        input_dim: 2,
        seq_len: 1,
        num_classes: 6,
    };
    let mut rows = Vec::new();
    for (name, fact) in [("fine (CUDA-style)", Factorization::Fine),
                         ("coarse (RenderScript)", Factorization::Coarse)] {
        let trace = build_trace_with_slots(shape, 1, fact, &TraceOpts::mobirnn(), profile.gpu_slots);
        // Look at the GEMM launches only (the figure's subject).
        let gemm: Vec<_> = trace.launches.iter().filter(|l| l.units[0].flops >= 2 * 32).collect();
        let units: usize = gemm.iter().map(|l| l.units.len()).sum();
        let r = gpu_run(profile, &trace, 0.0, 0);
        rows.push(Fig2Row {
            strategy: name,
            work_units: units,
            launches: gemm.len(),
            products_per_unit: 120 / units.max(1).min(120),
            sim_us: r.total_ns as f64 / 1e3,
        });
    }
    rows
}

// ---------------------------------------------------------------- Fig 3

/// Fig 3: CUDA-style (fine) GPU offload vs single-thread CPU.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub layers: usize,
    pub hidden: usize,
    /// Aggregate ms over TEST_CASES inferences.
    pub cpu_ms: f64,
    pub gpu_fine_ms: f64,
    /// How many times SLOWER the fine GPU port is (paper: up to ~4×).
    pub slowdown: f64,
}

pub fn fig3(profile: &DeviceProfile) -> Vec<Fig3Row> {
    COMPLEXITY_SWEEP
        .iter()
        .map(|&(layers, hidden)| {
            let shape = ModelShape::new(layers, hidden);
            let cpu = simulate_inference(profile, shape, 1, Target::CpuSingle, 0.0);
            let gpu = simulate_inference(profile, shape, 1, Target::Gpu(Factorization::Fine), 0.0);
            Fig3Row {
                layers,
                hidden,
                cpu_ms: ms(cpu) * TEST_CASES as f64,
                gpu_fine_ms: ms(gpu) * TEST_CASES as f64,
                slowdown: gpu as f64 / cpu as f64,
            }
        })
        .collect()
}

// ---------------------------------------------------------------- Fig 4

/// Fig 4: MobiRNN (coarse) GPU vs CPU on both phones, default model.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub device: String,
    pub cpu_ms: f64,
    pub gpu_ms: f64,
    pub speedup: f64,
}

pub fn fig4() -> Vec<Fig4Row> {
    let shape = ModelShape::default();
    [DeviceProfile::nexus5(), DeviceProfile::nexus6p()]
        .iter()
        .map(|p| {
            let cpu = simulate_inference(p, shape, 1, Target::CpuSingle, 0.0);
            let gpu = simulate_inference(p, shape, 1, Target::Gpu(Factorization::Coarse), 0.0);
            Fig4Row {
                device: p.name.clone(),
                cpu_ms: ms(cpu) * TEST_CASES as f64,
                gpu_ms: ms(gpu) * TEST_CASES as f64,
                speedup: cpu as f64 / gpu as f64,
            }
        })
        .collect()
}

// ---------------------------------------------------------------- Fig 5

/// Fig 5: GPU-over-CPU speedup as model complexity grows (Nexus 5).
#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub layers: usize,
    pub hidden: usize,
    pub params: usize,
    pub cpu_ms: f64,
    pub gpu_ms: f64,
    pub speedup: f64,
}

pub fn fig5(profile: &DeviceProfile) -> Vec<Fig5Row> {
    COMPLEXITY_SWEEP
        .iter()
        .map(|&(layers, hidden)| {
            let shape = ModelShape::new(layers, hidden);
            let cpu = simulate_inference(profile, shape, 1, Target::CpuSingle, 0.0);
            let gpu = simulate_inference(profile, shape, 1, Target::Gpu(Factorization::Coarse), 0.0);
            Fig5Row {
                layers,
                hidden,
                params: shape.param_count(),
                cpu_ms: ms(cpu),
                gpu_ms: ms(gpu),
                speedup: cpu as f64 / gpu as f64,
            }
        })
        .collect()
}

// ---------------------------------------------------------------- Fig 6

/// Fig 6: multi-threaded CPU vs GPU across complexity (Nexus 5).
#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub layers: usize,
    pub hidden: usize,
    pub cpu_single_ms: f64,
    pub cpu_multi_ms: f64,
    pub gpu_ms: f64,
    /// GPU advantage over the multithreaded CPU (paper: ~32% average).
    pub gpu_gain_over_mt: f64,
    /// Fraction of the GPU's benefit the MT CPU captures (paper: ≥70.5%).
    pub mt_benefit_fraction: f64,
}

pub fn fig6(profile: &DeviceProfile) -> Vec<Fig6Row> {
    COMPLEXITY_SWEEP
        .iter()
        .map(|&(layers, hidden)| {
            let shape = ModelShape::new(layers, hidden);
            let single = simulate_inference(profile, shape, 1, Target::CpuSingle, 0.0) as f64;
            let multi =
                simulate_inference(profile, shape, 1, Target::CpuMulti(profile.cpu_cores), 0.0)
                    as f64;
            let gpu =
                simulate_inference(profile, shape, 1, Target::Gpu(Factorization::Coarse), 0.0)
                    as f64;
            Fig6Row {
                layers,
                hidden,
                cpu_single_ms: single / 1e6,
                cpu_multi_ms: multi / 1e6,
                gpu_ms: gpu / 1e6,
                gpu_gain_over_mt: multi / gpu - 1.0,
                mt_benefit_fraction: (single - multi) / (single - gpu),
            }
        })
        .collect()
}

// ---------------------------------------------------------------- Fig 7

/// Fig 7: latency under background load (Nexus 6P in the paper).
#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub level: LoadLevel,
    /// Mean + spread of GPU latency over sampled utilizations (the dots).
    pub gpu_mean_ms: f64,
    pub gpu_min_ms: f64,
    pub gpu_max_ms: f64,
    /// CPU under the matching CPU load (the lines). The paper's "CPU"
    /// line is its standard single-thread implementation; cpu_multi is
    /// reported for context (§4.4 predicts MT shines on the 6P).
    pub cpu_single_ms: f64,
    pub cpu_multi_ms: f64,
    /// Whether offloading beats the paper's CPU line at this load level.
    pub gpu_wins: bool,
}

pub fn fig7(profile: &DeviceProfile, samples: usize, seed: u64) -> Vec<Fig7Row> {
    let shape = ModelShape::default();
    LoadLevel::ALL
        .iter()
        .map(|&level| {
            let mut trace = crate::simulator::load::LoadTrace::new(level, seed);
            let mut stats = Stats::new();
            for _ in 0..samples {
                let util = trace.sample();
                let ns =
                    simulate_inference(profile, shape, 1, Target::Gpu(Factorization::Coarse), util);
                stats.push(ms(ns));
            }
            let cpu_util = level.nominal_util();
            let cpu_single =
                ms(simulate_inference(profile, shape, 1, Target::CpuSingle, cpu_util));
            let cpu_multi = ms(simulate_inference(
                profile,
                shape,
                1,
                Target::CpuMulti(profile.cpu_cores),
                cpu_util,
            ));
            Fig7Row {
                level,
                gpu_mean_ms: stats.mean(),
                gpu_min_ms: stats.min(),
                gpu_max_ms: stats.max(),
                cpu_single_ms: cpu_single,
                cpu_multi_ms: cpu_multi,
                gpu_wins: stats.mean() < cpu_single,
            }
        })
        .collect()
}

// ------------------------------------------------------------ headline

/// The abstract's headline numbers, computed from the same series.
#[derive(Debug, Clone)]
pub struct Headline {
    pub mobirnn_speedup_nexus5: f64,
    pub mobirnn_speedup_nexus6p: f64,
    pub cuda_style_slowdown: f64,
    pub mt_benefit_fraction_min: f64,
    pub gpu_gain_over_mt_mean: f64,
}

pub fn headline() -> Headline {
    let f4 = fig4();
    let n5 = DeviceProfile::nexus5();
    let f3 = fig3(&n5);
    let f6 = fig6(&n5);
    Headline {
        mobirnn_speedup_nexus5: f4[0].speedup,
        mobirnn_speedup_nexus6p: f4[1].speedup,
        cuda_style_slowdown: f3.iter().map(|r| r.slowdown).fold(0.0, f64::max),
        mt_benefit_fraction_min: f6
            .iter()
            .map(|r| r.mt_benefit_fraction)
            .fold(f64::INFINITY, f64::min),
        gpu_gain_over_mt_mean: f6.iter().map(|r| r.gpu_gain_over_mt).sum::<f64>()
            / f6.len() as f64,
    }
}

// ------------------------------------------------------------- printing

pub fn print_fig2(rows: &[Fig2Row]) {
    println!("\n== Fig 2: factorization of a 32-dim x (32x120) gate GEMM ==");
    println!("{:<24} {:>6} {:>9} {:>14} {:>10}", "strategy", "units", "launches", "products/unit", "sim time");
    for r in rows {
        println!(
            "{:<24} {:>6} {:>9} {:>14} {:>8.1}µs",
            r.strategy, r.work_units, r.launches, r.products_per_unit, r.sim_us
        );
    }
}

pub fn print_fig3(rows: &[Fig3Row]) {
    println!("\n== Fig 3: CUDA-style GPU offload vs CPU (Nexus 5, {TEST_CASES} cases) ==");
    println!("{:<10} {:>12} {:>14} {:>10}", "model", "cpu (ms)", "gpu-fine (ms)", "slowdown");
    for r in rows {
        println!(
            "{:<10} {:>12.0} {:>14.0} {:>9.2}x",
            format!("{}l/{}h", r.layers, r.hidden),
            r.cpu_ms,
            r.gpu_fine_ms,
            r.slowdown
        );
    }
}

pub fn print_fig4(rows: &[Fig4Row]) {
    println!("\n== Fig 4: MobiRNN GPU vs CPU, default 2l/32h model ({TEST_CASES} cases) ==");
    println!("{:<10} {:>12} {:>12} {:>9}", "device", "cpu (ms)", "gpu (ms)", "speedup");
    for r in rows {
        println!("{:<10} {:>12.0} {:>12.0} {:>8.2}x", r.device, r.cpu_ms, r.gpu_ms, r.speedup);
    }
}

pub fn print_fig5(rows: &[Fig5Row]) {
    println!("\n== Fig 5: speedup vs model complexity (Nexus 5, per inference) ==");
    println!("{:<10} {:>9} {:>10} {:>10} {:>9}", "model", "params", "cpu (ms)", "gpu (ms)", "speedup");
    for r in rows {
        println!(
            "{:<10} {:>9} {:>10.1} {:>10.1} {:>8.2}x",
            format!("{}l/{}h", r.layers, r.hidden),
            r.params,
            r.cpu_ms,
            r.gpu_ms,
            r.speedup
        );
    }
}

pub fn print_fig6(rows: &[Fig6Row]) {
    println!("\n== Fig 6: multithreaded CPU vs GPU (Nexus 5, per inference) ==");
    println!(
        "{:<10} {:>10} {:>10} {:>9} {:>12} {:>12}",
        "model", "cpu-1t", "cpu-mt", "gpu", "gpu vs mt", "mt benefit"
    );
    for r in rows {
        println!(
            "{:<10} {:>8.1}ms {:>8.1}ms {:>7.1}ms {:>+11.1}% {:>11.1}%",
            format!("{}l/{}h", r.layers, r.hidden),
            r.cpu_single_ms,
            r.cpu_multi_ms,
            r.gpu_ms,
            100.0 * r.gpu_gain_over_mt,
            100.0 * r.mt_benefit_fraction
        );
    }
}

pub fn print_fig7(rows: &[Fig7Row]) {
    println!("\n== Fig 7: latency under background load (Nexus 6P, 2l/32h) ==");
    println!(
        "{:<18} {:>22} {:>10} {:>10} {:>9}",
        "load", "gpu mean [min..max]", "cpu-1t", "cpu-mt", "offload?"
    );
    for r in rows {
        println!(
            "{:<18} {:>7.1}ms [{:>5.1}..{:>6.1}] {:>8.1}ms {:>8.1}ms {:>9}",
            r.level.label(),
            r.gpu_mean_ms,
            r.gpu_min_ms,
            r.gpu_max_ms,
            r.cpu_single_ms,
            r.cpu_multi_ms,
            if r.gpu_wins { "gpu" } else { "cpu" }
        );
    }
}

pub fn print_headline(h: &Headline) {
    println!("\n== Headline (abstract) ==");
    println!("MobiRNN GPU speedup, Nexus 5 : {:.2}x   (paper: 3.93x)", h.mobirnn_speedup_nexus5);
    println!("MobiRNN GPU speedup, Nexus 6P: {:.2}x   (paper: 2.83x)", h.mobirnn_speedup_nexus6p);
    println!("CUDA-style port slowdown     : {:.2}x   (paper: ~4x slower)", h.cuda_style_slowdown);
    println!(
        "MT-CPU captures ≥ {:.1}% of GPU benefit   (paper: ≥70.5%)",
        100.0 * h.mt_benefit_fraction_min
    );
    println!(
        "GPU beats MT-CPU by {:.1}% on average      (paper: ~32%)",
        100.0 * h.gpu_gain_over_mt_mean
    );
}

/// Run + print everything (the `mobirnn figures --all` path).
pub fn run_all() {
    let n5 = DeviceProfile::nexus5();
    let n6p = DeviceProfile::nexus6p();
    print_fig2(&fig2(&n5));
    print_fig3(&fig3(&n5));
    print_fig4(&fig4());
    print_fig5(&fig5(&n5));
    print_fig6(&fig6(&n5));
    print_fig7(&fig7(&n6p, 30, 42));
    print_headline(&headline());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_packing_matches_paper_example() {
        let rows = fig2(&DeviceProfile::nexus5());
        let fine = &rows[0];
        let coarse = &rows[1];
        // "120 work units ... leading to 120 function calls"
        assert_eq!(fine.work_units, 120);
        assert_eq!(fine.launches, 120);
        // "12 work units that compute ten vector products each"
        assert_eq!(coarse.work_units, 12);
        assert_eq!(coarse.launches, 1);
        assert_eq!(coarse.products_per_unit, 10);
        assert!(fine.sim_us > coarse.sim_us);
    }

    #[test]
    fn fig3_fine_always_slower_and_up_to_4x() {
        let rows = fig3(&DeviceProfile::nexus5());
        for r in &rows {
            assert!(r.slowdown > 1.0, "{r:?}");
        }
        let max = rows.iter().map(|r| r.slowdown).fold(0.0, f64::max);
        assert!((3.0..5.0).contains(&max), "paper: up to ~4x, got {max}");
    }

    #[test]
    fn fig4_headline_speedups() {
        let rows = fig4();
        assert!((rows[0].speedup - 3.93).abs() < 0.4, "Nexus5: {}", rows[0].speedup);
        assert!((rows[1].speedup - 2.83).abs() < 0.4, "Nexus6P: {}", rows[1].speedup);
        // Paper: CPU faster on 6P, GPUs comparable.
        assert!(rows[1].cpu_ms < rows[0].cpu_ms);
        assert!((rows[1].gpu_ms / rows[0].gpu_ms - 1.0).abs() < 0.25);
        // Absolute anchor: ~142 ms/case CPU on Nexus 5.
        assert!((rows[0].cpu_ms / TEST_CASES as f64 - 142.0).abs() < 15.0);
    }

    #[test]
    fn fig5_rises_then_saturates_in_hidden() {
        let rows = fig5(&DeviceProfile::nexus5());
        let by = |l: usize, h: usize| rows.iter().find(|r| r.layers == l && r.hidden == h).unwrap();
        // Speedup grows with layers...
        assert!(by(2, 32).speedup > by(1, 32).speedup);
        assert!(by(3, 32).speedup >= by(2, 32).speedup * 0.99);
        // ...and with hidden until the bandwidth wall...
        assert!(by(2, 64).speedup > by(2, 32).speedup);
        assert!(by(2, 128).speedup > by(2, 64).speedup * 0.98);
        // ...then saturates (H=256 does NOT keep rising).
        assert!(by(2, 256).speedup < by(2, 128).speedup * 1.02);
        // And never collapses below the small-model speedup.
        assert!(by(2, 256).speedup > 0.8 * by(2, 32).speedup);
    }

    #[test]
    fn fig6_paper_claims() {
        let rows = fig6(&DeviceProfile::nexus5());
        for r in &rows {
            assert!(
                r.mt_benefit_fraction >= 0.705,
                "paper: MT captures >=70.5%, got {:?}",
                r
            );
            assert!(r.gpu_ms < r.cpu_multi_ms, "GPU still fastest: {r:?}");
        }
        let mean_gain: f64 =
            rows.iter().map(|r| r.gpu_gain_over_mt).sum::<f64>() / rows.len() as f64;
        assert!((0.1..0.6).contains(&mean_gain), "paper: ~32% mean GPU gain, got {mean_gain}");
    }

    #[test]
    fn fig7_crossover_at_high_load() {
        let rows = fig7(&DeviceProfile::nexus6p(), 20, 7);
        assert!(rows[0].gpu_wins, "low load: offload wins");
        assert!(rows[1].gpu_wins, "medium load: offload wins");
        assert!(!rows[2].gpu_wins, "high load: CPU wins (the paper's §4.5 result)");
        // Latency correlates with load (monotone mean).
        assert!(rows[0].gpu_mean_ms < rows[1].gpu_mean_ms);
        assert!(rows[1].gpu_mean_ms < rows[2].gpu_mean_ms);
        // Spread exists (the dots are a cloud, not a line).
        assert!(rows[2].gpu_max_ms > rows[2].gpu_min_ms);
    }

    #[test]
    fn headline_matches_abstract() {
        let h = headline();
        assert!(h.mobirnn_speedup_nexus5 > 3.5, "{h:?}");
        assert!(h.cuda_style_slowdown > 3.0 && h.cuda_style_slowdown < 5.0, "{h:?}");
        assert!(h.mt_benefit_fraction_min >= 0.705, "{h:?}");
        assert!(h.gpu_gain_over_mt_mean > 0.1, "{h:?}");
    }

    #[test]
    fn run_all_prints_without_panic() {
        run_all();
    }
}
