//! Streaming session subsystem (DESIGN.md §11).
//!
//! Turns the stateless batch classifier into a stateful streaming
//! service: each client opens a session, feeds frames incrementally
//! (`classify_stream`, per-step or per-chunk), and closes it — or lets
//! it expire. The per-client recurrent h/c state
//! ([`crate::lstm::StreamState`], one plane per layer, always f32)
//! lives in a sharded, lock-striped [`SessionStore`] shared by the
//! router, the scheduler, and every pool worker:
//!
//! - **Sharded, lock-striped**: sessions hash to `id & (shards - 1)`
//!   over a power-of-two shard count, one `Mutex<HashMap>` per shard —
//!   concurrent streams on different sessions almost never contend, and
//!   a worker holds exactly one shard lock while it advances one
//!   session's state.
//! - **TTL eviction on a monotonic clock**: every touch stamps
//!   nanoseconds since the store's `Instant` epoch; lookups past the
//!   TTL evict lazily, and the scheduler sweeps periodically. All
//!   expiry APIs take an explicit `now_ns` so tests drive time
//!   deterministically.
//! - **Engine-agnostic state**: h/c planes live here, *not* inside any
//!   engine's arena, so session affinity is a scheduling pin
//!   (`Session::target`) rather than a data dependency — failover
//!   migrates a stream by re-pinning and bumping `sessions_migrated`,
//!   no state copy required.

pub mod store;

pub use store::{Session, SessionError, SessionStore};
