//! The sharded, lock-striped session store (see module docs in
//! [`crate::session`]).

use std::collections::HashMap;
use std::error::Error;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::ModelShape;
use crate::coordinator::Precision;
use crate::lstm::StreamState;
use crate::simulator::Target;

/// Typed session-lookup failure. `Expired` means the entry existed but
/// its TTL had lapsed — the lookup evicted it (lazy expiry); the caller
/// owns the matching metrics update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionError {
    NotFound(u64),
    Expired(u64),
}

impl fmt::Display for SessionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionError::NotFound(id) => write!(f, "session {id} not found"),
            SessionError::Expired(id) => write!(f, "session {id} expired"),
        }
    }
}

impl Error for SessionError {}

/// One live stream: the persistent recurrent state plus the scheduling
/// pin and bookkeeping stamps.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    /// Precision class fixed at open: int8 sessions pin to the quant
    /// pool; f32 sessions never land on it (PR 4's no-cross-precision
    /// contract).
    pub precision: Precision,
    /// Session affinity: the engine target this stream is pinned to.
    /// Authoritative — the scheduler's affinity map is a cache of this
    /// field. Rewritten (with a `sessions_migrated` bump) when failover
    /// lands the stream on a different pool.
    pub target: Target,
    /// The recurrent h/c planes (always f32, even for int8 sessions).
    pub state: StreamState,
    /// Frames successfully served, counted by the session layer (the
    /// pool worker) so the tally holds for ANY engine implementation —
    /// echoed to the client on close.
    pub steps: u64,
    /// Monotonic ns (store epoch) of the last successful touch.
    pub last_touch_ns: u64,
    pub opened_ns: u64,
}

/// Sharded, lock-striped map of live sessions. Cheap to share
/// (`Arc<SessionStore>`); all methods take `&self`.
pub struct SessionStore {
    shards: Vec<Mutex<HashMap<u64, Session>>>,
    shard_mask: u64,
    ttl_ns: u64,
    next_id: AtomicU64,
    epoch: Instant,
}

impl SessionStore {
    /// Default striping: 16 shards.
    pub fn new(ttl: Duration) -> Self {
        Self::with_shards(ttl, 16)
    }

    /// `shards` is rounded up to a power of two (min 1) so the stripe
    /// function is a mask, not a division.
    pub fn with_shards(ttl: Duration, shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_mask: (n - 1) as u64,
            ttl_ns: ttl.as_nanos() as u64,
            next_id: AtomicU64::new(1),
            epoch: Instant::now(),
        }
    }

    /// Monotonic nanoseconds since the store was created — the clock
    /// every `now_ns` argument below is measured on.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    pub fn ttl(&self) -> Duration {
        Duration::from_nanos(self.ttl_ns)
    }

    fn shard(&self, id: u64) -> &Mutex<HashMap<u64, Session>> {
        &self.shards[(id & self.shard_mask) as usize]
    }

    /// Open a new session pinned to `target`; returns its id. Ids are
    /// sequential (they stripe uniformly under the mask) and never
    /// reused within a store's lifetime.
    pub fn open(&self, shape: ModelShape, precision: Precision, target: Target) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let now = self.now_ns();
        let session = Session {
            id,
            precision,
            target,
            state: StreamState::new(shape),
            steps: 0,
            last_touch_ns: now,
            opened_ns: now,
        };
        self.shard(id).lock().unwrap().insert(id, session);
        id
    }

    /// Run `f` against the live session under its shard lock, touching
    /// its TTL stamp. A lapsed entry is evicted here (lazy expiry) and
    /// reported as [`SessionError::Expired`].
    pub fn with<R>(
        &self,
        id: u64,
        now_ns: u64,
        f: impl FnOnce(&mut Session) -> R,
    ) -> Result<R, SessionError> {
        let mut shard = self.shard(id).lock().unwrap();
        let expired = match shard.get_mut(&id) {
            None => return Err(SessionError::NotFound(id)),
            Some(sess) => now_ns.saturating_sub(sess.last_touch_ns) > self.ttl_ns,
        };
        if expired {
            shard.remove(&id);
            return Err(SessionError::Expired(id));
        }
        let sess = shard.get_mut(&id).expect("checked above");
        sess.last_touch_ns = now_ns;
        Ok(f(sess))
    }

    /// The session's current affinity pin (touches the TTL stamp).
    pub fn target_of(&self, id: u64, now_ns: u64) -> Result<Target, SessionError> {
        self.with(id, now_ns, |s| s.target)
    }

    /// Re-pin a session after failover migrated it to a different pool.
    /// No TTL check: the migrating worker just served the stream, so
    /// the session is by definition live. Returns false if it vanished
    /// (closed/evicted concurrently).
    pub fn set_target(&self, id: u64, target: Target) -> bool {
        match self.shard(id).lock().unwrap().get_mut(&id) {
            Some(sess) => {
                sess.target = target;
                true
            }
            None => false,
        }
    }

    /// Close a session; returns the steps it consumed, or None if it
    /// did not exist (already closed or evicted).
    pub fn close(&self, id: u64) -> Option<u64> {
        self.shard(id).lock().unwrap().remove(&id).map(|s| s.steps)
    }

    /// Sweep every shard, evicting sessions whose TTL lapsed before
    /// `now_ns`. Returns the evicted ids (the caller updates metrics
    /// and its affinity cache).
    pub fn evict_expired(&self, now_ns: u64) -> Vec<u64> {
        let mut evicted = Vec::new();
        for shard in &self.shards {
            let mut shard = shard.lock().unwrap();
            shard.retain(|id, sess| {
                let live = now_ns.saturating_sub(sess.last_touch_ns) <= self.ttl_ns;
                if !live {
                    evicted.push(*id);
                }
                live
            });
        }
        evicted
    }

    pub fn contains(&self, id: u64) -> bool {
        self.shard(id).lock().unwrap().contains_key(&id)
    }

    /// Number of live (possibly TTL-lapsed but not yet swept) sessions.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(ttl_ms: u64, shards: usize) -> SessionStore {
        SessionStore::with_shards(Duration::from_millis(ttl_ms), shards)
    }

    fn shape() -> ModelShape {
        ModelShape { num_layers: 2, hidden: 4, input_dim: 3, seq_len: 5, num_classes: 3 }
    }

    #[test]
    fn open_with_close_roundtrip() {
        let st = store(1000, 4);
        let id = st.open(shape(), Precision::F32, Target::CpuSingle);
        assert!(st.contains(id));
        assert_eq!(st.len(), 1);
        let tgt = st.target_of(id, st.now_ns()).unwrap();
        assert_eq!(tgt, Target::CpuSingle);
        let steps = st.with(id, st.now_ns(), |s| s.state.steps()).unwrap();
        assert_eq!(steps, 0);
        // The session layer's tally is what close echoes back.
        st.with(id, st.now_ns(), |s| s.steps += 5).unwrap();
        assert_eq!(st.close(id), Some(5));
        assert!(!st.contains(id));
        assert_eq!(st.close(id), None);
    }

    #[test]
    fn missing_session_is_not_found() {
        let st = store(1000, 4);
        assert_eq!(st.target_of(99, 0).unwrap_err(), SessionError::NotFound(99));
    }

    #[test]
    fn lazy_expiry_on_lookup() {
        // Synthetic clock: expiry is a pure function of now_ns, no
        // sleeps needed.
        let st = store(10, 4); // 10ms TTL
        let id = st.open(shape(), Precision::F32, Target::CpuSingle);
        let opened = st.with(id, st.now_ns(), |s| s.opened_ns).unwrap();
        let past_ttl = opened + 11_000_000;
        assert_eq!(st.target_of(id, past_ttl).unwrap_err(), SessionError::Expired(id));
        // Lazy expiry removed it: a second lookup is NotFound.
        assert_eq!(st.target_of(id, past_ttl).unwrap_err(), SessionError::NotFound(id));
    }

    #[test]
    fn touch_extends_ttl() {
        let st = store(10, 1);
        let id = st.open(shape(), Precision::F32, Target::CpuSingle);
        let opened = st.with(id, st.now_ns(), |s| s.opened_ns).unwrap();
        // Touch at +8ms, then look up at +16ms: 8ms since last touch,
        // still live.
        assert!(st.with(id, opened + 8_000_000, |_| ()).is_ok());
        assert!(st.target_of(id, opened + 16_000_000).is_ok());
        // But +8ms touch then +20ms lookup (12ms gap) expires.
        assert_eq!(
            st.target_of(id, opened + 20_000_000 + 8_000_000).unwrap_err(),
            SessionError::Expired(id)
        );
    }

    #[test]
    fn sweep_evicts_only_lapsed() {
        let st = store(10, 8);
        let a = st.open(shape(), Precision::F32, Target::CpuSingle);
        let b = st.open(shape(), Precision::Int8, Target::CpuQuant);
        let opened = st.with(a, st.now_ns(), |s| s.opened_ns).unwrap();
        // Keep b fresh at +9ms, then sweep at +15ms: only a lapses.
        st.with(b, opened + 9_000_000, |_| ()).unwrap();
        let evicted = st.evict_expired(opened + 15_000_000);
        assert_eq!(evicted, vec![a]);
        assert!(!st.contains(a));
        assert!(st.contains(b));
        assert!(st.evict_expired(opened + 15_000_000).is_empty());
    }

    #[test]
    fn set_target_repins() {
        let st = store(1000, 2);
        let id = st.open(shape(), Precision::F32, Target::CpuSingle);
        assert!(st.set_target(id, Target::CpuMulti(4)));
        assert_eq!(st.target_of(id, st.now_ns()).unwrap(), Target::CpuMulti(4));
        st.close(id);
        assert!(!st.set_target(id, Target::CpuSingle));
    }

    #[test]
    fn ids_stripe_across_shards() {
        let st = store(1000, 4);
        for _ in 0..16 {
            st.open(shape(), Precision::F32, Target::CpuSingle);
        }
        assert_eq!(st.len(), 16);
        // Sequential ids under a power-of-two mask hit every shard.
        let per_shard: Vec<usize> = st.shards.iter().map(|s| s.lock().unwrap().len()).collect();
        assert_eq!(per_shard, vec![4, 4, 4, 4]);
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let st = store(1000, 5);
        assert_eq!(st.shards.len(), 8);
        let st = store(1000, 0);
        assert_eq!(st.shards.len(), 1);
    }
}
