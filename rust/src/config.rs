//! Configuration: model shapes, artifact manifest, serving options.
//!
//! The artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) is the single source of truth about what was
//! AOT-compiled: variant shapes, HLO/weight file names, parameter order.
//! Rust never guesses shapes — it reads them from here (via the in-crate
//! JSON parser, [`crate::json`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::{self, Value};

/// Static shape of one model (mirror of python ModelConfig, paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelShape {
    pub num_layers: usize,
    pub hidden: usize,
    pub input_dim: usize,
    pub seq_len: usize,
    pub num_classes: usize,
}

impl Default for ModelShape {
    /// Paper default: 2 layers x 32 hidden, 128x9 windows, 6 classes.
    fn default() -> Self {
        Self { num_layers: 2, hidden: 32, input_dim: 9, seq_len: 128, num_classes: 6 }
    }
}

impl ModelShape {
    pub fn new(num_layers: usize, hidden: usize) -> Self {
        Self { num_layers, hidden, ..Self::default() }
    }

    pub fn variant_name(&self, batch: usize) -> String {
        format!("lstm_L{}_H{}_B{batch}", self.num_layers, self.hidden)
    }

    /// Exact trainable parameter count; mirrors ModelConfig.param_count().
    pub fn param_count(&self) -> usize {
        let mut n = 0;
        let mut in_dim = self.input_dim;
        for _ in 0..self.num_layers {
            n += (in_dim + self.hidden) * 4 * self.hidden + 4 * self.hidden;
            in_dim = self.hidden;
        }
        n + self.hidden * self.num_classes + self.num_classes
    }

    /// FLOPs for one forward pass at batch 1 (2*M*N*K per GEMM + pointwise).
    pub fn flops_per_inference(&self) -> u64 {
        let mut total: u64 = 0;
        let mut in_dim = self.input_dim as u64;
        let h = self.hidden as u64;
        for _ in 0..self.num_layers {
            let gemm = 2 * (in_dim + h) * 4 * h; // [1, I+H] @ [I+H, 4H]
            let pointwise = 9 * h; // 3 sigmoids + 2 tanh + mul/add, amortized
            total += (gemm + pointwise) * self.seq_len as u64;
            in_dim = h;
        }
        total + 2 * h * self.num_classes as u64
    }

    /// Weight bytes streamed per *timestep* (all layers, f32) — the memory
    /// traffic term behind the paper's Fig 5 bandwidth saturation.
    pub fn weight_bytes_per_step(&self) -> u64 {
        let mut bytes: u64 = 0;
        let mut in_dim = self.input_dim as u64;
        let h = self.hidden as u64;
        for _ in 0..self.num_layers {
            bytes += ((in_dim + h) * 4 * h + 4 * h) * 4;
            in_dim = h;
        }
        bytes
    }
}

/// One AOT-compiled variant as recorded in the manifest.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub name: String,
    pub num_layers: usize,
    pub hidden: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub hlo: String,
    pub weights: String,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub param_count: usize,
    pub trained: bool,
    pub block_h: usize,
    pub vmem_bytes: u64,
    pub mxu_utilization: f64,
}

impl VariantInfo {
    pub fn shape(&self) -> ModelShape {
        ModelShape {
            num_layers: self.num_layers,
            hidden: self.hidden,
            input_dim: self.input_dim,
            seq_len: self.seq_len,
            num_classes: self.num_classes,
        }
    }

    fn from_json(v: &Value) -> Result<Self> {
        let sfield = |k: &str| -> Result<String> {
            Ok(v.req(k).map_err(|e| anyhow!(e))?.as_str().context(format!("{k} not a string"))?.to_string())
        };
        let ufield = |k: &str| -> Result<usize> {
            v.req(k).map_err(|e| anyhow!(e))?.as_usize().context(format!("{k} not a usize"))
        };
        let param_names: Vec<String> = v
            .get("param_names")
            .as_arr()
            .context("param_names")?
            .iter()
            .map(|p| p.as_str().map(str::to_string).context("param name"))
            .collect::<Result<_>>()?;
        let param_shapes: Vec<Vec<usize>> = v
            .get("param_shapes")
            .as_arr()
            .context("param_shapes")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .context("shape not arr")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<_>>()?;
        Ok(Self {
            name: sfield("name")?,
            num_layers: ufield("num_layers")?,
            hidden: ufield("hidden")?,
            batch: ufield("batch")?,
            seq_len: ufield("seq_len")?,
            input_dim: ufield("input_dim")?,
            num_classes: ufield("num_classes")?,
            hlo: sfield("hlo")?,
            weights: sfield("weights")?,
            param_names,
            param_shapes,
            param_count: ufield("param_count")?,
            trained: v.get("trained").as_bool().unwrap_or(false),
            block_h: v.get("block_h").as_usize().unwrap_or(0),
            vmem_bytes: v.get("vmem_bytes").as_f64().unwrap_or(0.0) as u64,
            mxu_utilization: v.get("mxu_utilization").as_f64().unwrap_or(0.0),
        })
    }
}

#[derive(Debug, Clone)]
pub struct GoldenInfo {
    pub file: String,
    pub variant: String,
    pub batch: usize,
    pub labels: Vec<u32>,
    pub predictions: Vec<u32>,
}

#[derive(Debug, Clone)]
pub struct HarTestInfo {
    pub file: String,
    pub n: usize,
    pub seq_len: usize,
    pub channels: usize,
    pub classes: usize,
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f64,
    pub train_accuracy: f64,
    pub test_accuracy: f64,
    pub param_count: usize,
}

/// `artifacts/manifest.json` — index of everything `make artifacts` built.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub default_variant: String,
    pub variants: Vec<VariantInfo>,
    pub golden: GoldenInfo,
    pub har_test: HarTestInfo,
    pub train_report: TrainReport,
    pub hashes: HashMap<String, String>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        if root.get("format").as_str() != Some("mobirnn-artifacts") {
            return Err(anyhow!("unexpected manifest format {:?}", root.get("format")));
        }

        let variants: Vec<VariantInfo> = root
            .get("variants")
            .as_arr()
            .context("variants")?
            .iter()
            .map(VariantInfo::from_json)
            .collect::<Result<_>>()?;

        let g = root.req("golden").map_err(|e| anyhow!(e))?;
        let u32s = |v: &Value| -> Vec<u32> {
            v.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize().map(|u| u as u32))
                .collect()
        };
        let golden = GoldenInfo {
            file: g.get("file").as_str().context("golden.file")?.to_string(),
            variant: g.get("variant").as_str().context("golden.variant")?.to_string(),
            batch: g.get("batch").as_usize().context("golden.batch")?,
            labels: u32s(g.get("labels")),
            predictions: u32s(g.get("predictions")),
        };

        let h = root.req("har_test").map_err(|e| anyhow!(e))?;
        let har_test = HarTestInfo {
            file: h.get("file").as_str().context("har_test.file")?.to_string(),
            n: h.get("n").as_usize().context("har_test.n")?,
            seq_len: h.get("seq_len").as_usize().context("har_test.seq_len")?,
            channels: h.get("channels").as_usize().context("har_test.channels")?,
            classes: h.get("classes").as_usize().context("har_test.classes")?,
        };

        let t = root.req("train_report").map_err(|e| anyhow!(e))?;
        let train_report = TrainReport {
            steps: t.get("steps").as_usize().unwrap_or(0),
            final_loss: t.get("final_loss").as_f64().unwrap_or(f64::NAN),
            train_accuracy: t.get("train_accuracy").as_f64().unwrap_or(0.0),
            test_accuracy: t.get("test_accuracy").as_f64().unwrap_or(0.0),
            param_count: t.get("param_count").as_usize().unwrap_or(0),
        };

        let hashes = root
            .get("hashes")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();

        let man = Manifest {
            default_variant: root
                .get("default_variant")
                .as_str()
                .context("default_variant")?
                .to_string(),
            variants,
            golden,
            har_test,
            train_report,
            hashes,
            dir: dir.to_path_buf(),
        };

        // Every referenced file must exist; shapes must be coherent.
        for v in &man.variants {
            for f in [&v.hlo, &v.weights] {
                let p = dir.join(f);
                if !p.exists() {
                    return Err(anyhow!("manifest references missing file {p:?}"));
                }
            }
            if v.param_names.len() != v.param_shapes.len() {
                return Err(anyhow!("variant {}: param names/shapes mismatch", v.name));
            }
        }
        Ok(man)
    }

    /// Default artifact dir: $MOBIRNN_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("MOBIRNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| anyhow!("variant {name:?} not in manifest"))
    }

    /// Find a variant by shape and exact batch.
    pub fn variant_for(&self, shape: ModelShape, batch: usize) -> Option<&VariantInfo> {
        self.variants.iter().find(|v| v.shape() == shape && v.batch == batch)
    }

    /// The compiled batch sizes available for a shape, ascending.
    pub fn batches_for(&self, shape: ModelShape) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .variants
            .iter()
            .filter(|v| v.shape() == shape)
            .map(|v| v.batch)
            .collect();
        bs.sort_unstable();
        bs
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_shape_matches_paper() {
        let s = ModelShape::default();
        assert_eq!((s.num_layers, s.hidden), (2, 32));
        assert_eq!((s.seq_len, s.input_dim, s.num_classes), (128, 9, 6));
    }

    #[test]
    fn param_count_matches_python() {
        // Mirrors test_model.py::test_param_count_paper_default.
        assert_eq!(ModelShape::default().param_count(), 13894);
        // Paper §4.3: 2l/128h has ~4x the parameters of 2l/64h.
        let p64 = ModelShape::new(2, 64).param_count() as f64;
        let p128 = ModelShape::new(2, 128).param_count() as f64;
        assert!(p128 / p64 > 3.5 && p128 / p64 < 4.5);
    }

    #[test]
    fn flops_scale_with_layers() {
        let f1 = ModelShape::new(1, 32).flops_per_inference();
        let f3 = ModelShape::new(3, 32).flops_per_inference();
        assert!(f3 > 2 * f1);
    }

    #[test]
    fn weight_bytes_quadratic_in_hidden() {
        let b32 = ModelShape::new(2, 32).weight_bytes_per_step() as f64;
        let b128 = ModelShape::new(2, 128).weight_bytes_per_step() as f64;
        assert!(b128 / b32 > 8.0, "expected superlinear growth: {}", b128 / b32);
    }

    #[test]
    fn variant_names() {
        assert_eq!(ModelShape::new(2, 32).variant_name(4), "lstm_L2_H32_B4");
    }

    #[test]
    fn manifest_loads_real_artifacts_if_present() {
        // Integration-ish: when artifacts/ exists (after `make artifacts`),
        // the manifest must parse and self-validate.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(!man.variants.is_empty());
        let def = man.variant(&man.default_variant).unwrap();
        assert_eq!(def.shape(), ModelShape::default());
        assert!(!man.batches_for(ModelShape::default()).is_empty());
        assert_eq!(man.golden.labels.len(), man.golden.batch);
        assert!(man.train_report.test_accuracy > 0.3);
    }

    #[test]
    fn manifest_rejects_missing_files() {
        let tmp = std::env::temp_dir().join(format!("mobirnn_man_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let manifest = r#"{
            "format": "mobirnn-artifacts", "version": 1,
            "default_variant": "v",
            "variants": [{"name":"v","num_layers":1,"hidden":8,"batch":1,
              "seq_len":4,"input_dim":2,"num_classes":3,
              "hlo":"missing.hlo.txt","weights":"missing.mrnw",
              "param_names":["a"],"param_shapes":[[1]],"param_count":1}],
            "golden": {"file":"g","variant":"v","batch":1,"labels":[0],"predictions":[0]},
            "har_test": {"file":"h","n":1,"seq_len":4,"channels":2,"classes":3},
            "train_report": {"steps":1,"final_loss":0.1,"train_accuracy":1,"test_accuracy":1,"param_count":1}
        }"#;
        std::fs::write(tmp.join("manifest.json"), manifest).unwrap();
        let err = Manifest::load(&tmp).unwrap_err().to_string();
        assert!(err.contains("missing file"), "{err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn manifest_rejects_wrong_format() {
        let tmp = std::env::temp_dir().join(format!("mobirnn_man2_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), r#"{"format": "other"}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
