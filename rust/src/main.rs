//! `mobirnn` — the launcher / CLI.
//!
//! ```text
//! mobirnn figures [--fig 2|3|4|5|6|7] [--all]     regenerate paper figures
//! mobirnn serve   [--addr A] [--policy P] [--device D] [--max-wait-ms N]
//! mobirnn classify [--n N] [--policy P] [--device D] [--gpu-load U]
//! mobirnn info                                      artifact manifest summary
//! ```
//!
//! (The vendored crate set has no clap; parsing is a small hand-rolled
//! flag walker — see `Args`.)

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use mobirnn::config::Manifest;
use mobirnn::coordinator::{DeviceState, OffloadPolicy, Router, RouterConfig};
use mobirnn::figures;
use mobirnn::har;
use mobirnn::runtime::Runtime;
use mobirnn::server::Server;
use mobirnn::simulator::DeviceProfile;

/// Tiny flag parser: `--key value` and `--flag` pairs after a subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut argv = std::env::args().skip(1);
        let cmd = argv.next().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i].trim_start_matches('-').to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.insert(k, rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(k, "true".into());
                i += 1;
            }
        }
        Self { cmd, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

fn main() {
    let args = Args::parse();
    let r = match args.cmd.as_str() {
        "figures" => cmd_figures(&args),
        "serve" => cmd_serve(&args),
        "classify" => cmd_classify(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mobirnn — MobiRNN (EMDL'17) serving reproduction\n\
         \n\
         USAGE: mobirnn <command> [flags]\n\
         \n\
         COMMANDS:\n\
         \x20 figures   regenerate paper figures   [--fig N | --all]\n\
         \x20 serve     TCP serving front-end      [--addr 127.0.0.1:7878] [--policy cost-model]\n\
         \x20                                      [--device nexus5|nexus6p] [--max-wait-ms 2]\n\
         \x20 classify  run N windows through the local router\n\
         \x20                                      [--n 10] [--policy P] [--gpu-load 0.x]\n\
         \x20 info      print the artifact manifest summary\n\
         \n\
         POLICIES: gpu | fine | cpu | cpu-multi | threshold:<0..1> | cost-model"
    );
}

fn cmd_figures(args: &Args) -> Result<()> {
    let n5 = DeviceProfile::nexus5();
    let n6p = DeviceProfile::nexus6p();
    match args.get("fig") {
        None => figures::run_all(),
        Some("2") => figures::print_fig2(&figures::fig2(&n5)),
        Some("3") => figures::print_fig3(&figures::fig3(&n5)),
        Some("4") => figures::print_fig4(&figures::fig4()),
        Some("5") => figures::print_fig5(&figures::fig5(&n5)),
        Some("6") => figures::print_fig6(&figures::fig6(&n5)),
        Some("7") => figures::print_fig7(&figures::fig7(&n6p, 30, 42)),
        Some(other) => return Err(anyhow!("unknown figure {other}")),
    }
    Ok(())
}

fn build_router(args: &Args) -> Result<(Router, Manifest)> {
    let manifest = Manifest::load_default()?;
    let device_name = args.get_or("device", "nexus5");
    let profile = DeviceProfile::by_name(&device_name)
        .ok_or_else(|| anyhow!("unknown device {device_name:?} (nexus5|nexus6p)"))?;
    let policy = OffloadPolicy::parse(&args.get_or("policy", "cost-model"))
        .ok_or_else(|| anyhow!("bad --policy (see --help)"))?;
    let max_wait: u64 = args.get_or("max-wait-ms", "2").parse().context("--max-wait-ms")?;
    let device = DeviceState::new(profile);
    if let Some(u) = args.get("gpu-load") {
        device.set_gpu_util(u.parse().context("--gpu-load")?);
    }
    if let Some(u) = args.get("cpu-load") {
        device.set_cpu_util(u.parse().context("--cpu-load")?);
    }
    let runtime = Runtime::start(&manifest)?;
    let router = Router::start(
        &manifest,
        runtime,
        device,
        RouterConfig {
            policy,
            max_wait: Duration::from_millis(max_wait),
            ..Default::default()
        },
    )?;
    Ok((router, manifest))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let (router, manifest) = build_router(args)?;
    let server = Server::bind(&addr, router)?;
    println!(
        "mobirnn serving {} on {} (policy {}, device {}) — JSON lines; Ctrl-C to stop",
        manifest.default_variant,
        server.addr(),
        args.get_or("policy", "cost-model"),
        args.get_or("device", "nexus5"),
    );
    // Serve forever.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_classify(args: &Args) -> Result<()> {
    let n: usize = args.get_or("n", "10").parse().context("--n")?;
    let (router, manifest) = build_router(args)?;
    let ds = har::HarDataset::load(manifest.path(&manifest.har_test.file))?;
    let n = n.min(ds.len());
    println!("classifying {n} windows from {} ...", manifest.har_test.file);
    let t0 = Instant::now();
    let mut correct = 0;
    for i in 0..n {
        let reply = router.classify(ds.window(i).to_vec())?;
        let gold = ds.labels[i] as usize;
        if reply.class == gold {
            correct += 1;
        }
        if i < 10 || i % 100 == 0 {
            println!(
                "  #{i:<4} pred={:<18} gold={:<18} target={:<9} sim={:.1}ms wall={:.2}ms",
                reply.label,
                har::CLASS_NAMES[gold],
                reply.target,
                reply.sim_ns as f64 / 1e6,
                reply.wall_ns as f64 / 1e6,
            );
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "\naccuracy {}/{n} = {:.1}%   wall {:.2}s ({:.1} inf/s)",
        correct,
        100.0 * correct as f64 / n as f64,
        elapsed.as_secs_f64(),
        n as f64 / elapsed.as_secs_f64()
    );
    println!("metrics: {}", router.metrics.to_json().to_json());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let man = Manifest::load_default()?;
    println!("artifacts: {:?}", man.dir);
    println!(
        "trained model: {} (test_acc {:.1}%, {} params, {} train steps)",
        man.default_variant,
        100.0 * man.train_report.test_accuracy,
        man.train_report.param_count,
        man.train_report.steps
    );
    println!("har test set: {} windows", man.har_test.n);
    println!("variants:");
    for v in &man.variants {
        println!(
            "  {:<18} batch {:<2} {}  block_h={} vmem={}KiB mxu={:.1}%",
            v.name,
            v.batch,
            if v.trained { "trained" } else { "seeded " },
            v.block_h,
            v.vmem_bytes / 1024,
            100.0 * v.mxu_utilization,
        );
    }
    Ok(())
}
