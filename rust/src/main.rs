//! `mobirnn` — the launcher / CLI.
//!
//! ```text
//! mobirnn figures [--fig 2|3|4|5|6|7] [--all]     regenerate paper figures
//! mobirnn serve   [--addr A] [--policy P] [--device D] [--max-wait-ms N]
//!                 [--io-threads N] [--proto 2|3]
//! mobirnn classify [--n N] [--policy P] [--device D] [--gpu-load U] [--target T]
//! mobirnn info                                      artifact manifest summary
//! ```
//!
//! (The vendored crate set has no clap; parsing is a small hand-rolled
//! flag walker — see `Args`. Unknown flags are rejected, value flags
//! always consume the next token — even one that starts with `-`, e.g.
//! `--gpu-load -0.5` — and a value flag at the end of the line is a
//! "missing value" error instead of being silently swallowed.)

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use mobirnn::config::Manifest;
use mobirnn::coordinator::{
    parse_target, ClassifyOptions, DeviceState, OffloadPolicy, Precision, Router,
};
use mobirnn::figures;
use mobirnn::har;
use mobirnn::runtime::Runtime;
use mobirnn::server::{EventServer, Server};
use mobirnn::simulator::DeviceProfile;

/// Per-command flag specification: which `--key value` flags and which
/// bare `--flag` switches a command accepts.
fn flag_spec(cmd: &str) -> (&'static [&'static str], &'static [&'static str]) {
    match cmd {
        "figures" => (&["fig"], &["all"]),
        "serve" => (
            &[
                "addr",
                "policy",
                "device",
                "max-wait-ms",
                "cpu-threads",
                "gpu-load",
                "cpu-load",
                "max-queue",
                "max-connections",
                "idle-timeout-ms",
                "session-ttl-ms",
                "proto",
                "io-threads",
                "fault-plan",
            ],
            &["force-scalar"],
        ),
        "classify" => (
            &[
                "n",
                "policy",
                "device",
                "max-wait-ms",
                "cpu-threads",
                "gpu-load",
                "cpu-load",
                "target",
                "precision",
                "max-queue",
                "fault-plan",
            ],
            &["force-scalar"],
        ),
        _ => (&[], &[]),
    }
}

/// Tiny flag parser: `--key value` and `--flag` pairs after a subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut argv = std::env::args().skip(1);
        let cmd = argv.next().unwrap_or_else(|| "help".into());
        let rest: Vec<String> = argv.collect();
        Self::from_parts(&cmd, &rest)
    }

    /// Walk `rest` against the command's flag spec. Testable without env.
    fn from_parts(cmd: &str, rest: &[String]) -> Result<Self> {
        let (value_flags, bool_flags) = flag_spec(cmd);
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < rest.len() {
            let arg = &rest[i];
            let name = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument {arg:?} (flags start with --)"))?;
            if value_flags.iter().any(|f| *f == name) {
                let value = rest
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{name} requires a value"))?;
                flags.insert(name.to_string(), value.clone());
                i += 2;
            } else if bool_flags.iter().any(|f| *f == name) {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            } else {
                return Err(anyhow!("unknown flag --{name} for {cmd:?} (see --help)"));
            }
        }
        Ok(Self { cmd: cmd.to_string(), flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

/// Parse a `--gpu-load`/`--cpu-load` value; must be a utilization in [0, 1].
fn parse_util(flag: &str, raw: &str) -> Result<f64> {
    let u: f64 = raw.parse().with_context(|| format!("--{flag} {raw:?}"))?;
    if !(0.0..=1.0).contains(&u) {
        return Err(anyhow!("--{flag} {u} outside [0, 1]"));
    }
    Ok(u)
}

fn main() {
    let args = match Args::parse() {
        Ok(a) => a,
        Err(e) => {
            print_help();
            eprintln!("\nerror: {e:#}");
            std::process::exit(2);
        }
    };
    let r = match args.cmd.as_str() {
        "figures" => cmd_figures(&args),
        "serve" => cmd_serve(&args),
        "classify" => cmd_classify(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mobirnn — MobiRNN (EMDL'17) serving reproduction\n\
         \n\
         USAGE: mobirnn <command> [flags]\n\
         \n\
         COMMANDS:\n\
         \x20 figures   regenerate paper figures   [--fig N | --all]\n\
         \x20 serve     TCP serving front-end      [--addr 127.0.0.1:7878] [--policy cost-model]\n\
         \x20                                      [--device nexus5|nexus6p] [--max-wait-ms 2]\n\
         \x20                                      [--cpu-threads 4] [--gpu-load U] [--cpu-load U]\n\
         \x20                                      [--max-queue 256] [--max-connections 64]\n\
         \x20                                      [--idle-timeout-ms 0 (never)]\n\
         \x20                                      [--session-ttl-ms 30000]\n\
         \x20                                      [--io-threads 0 (thread-per-conn)] [--proto 2|3]\n\
         \x20                                      [--fault-plan \"cpu:fail_rate=0.2,...\"]\n\
         \x20                                      [--force-scalar]\n\
         \x20 classify  run N windows through the local router\n\
         \x20                                      [--n 10] [--policy P] [--gpu-load 0.x]\n\
         \x20                                      [--target gpu|cpu|cpu-multi|cpu-quant]\n\
         \x20                                      [--precision f32|int8] [--force-scalar]\n\
         \x20                                      [--fault-plan PLAN (or MOBIRNN_FAULT_PLAN)]\n\
         \x20 info      print the artifact manifest summary\n\
         \n\
         POLICIES: gpu | fine | cpu | cpu-multi | threshold:<0..1> | cost-model"
    );
}

fn cmd_figures(args: &Args) -> Result<()> {
    let n5 = DeviceProfile::nexus5();
    let n6p = DeviceProfile::nexus6p();
    match args.get("fig") {
        None => figures::run_all(),
        Some("2") => figures::print_fig2(&figures::fig2(&n5)),
        Some("3") => figures::print_fig3(&figures::fig3(&n5)),
        Some("4") => figures::print_fig4(&figures::fig4()),
        Some("5") => figures::print_fig5(&figures::fig5(&n5)),
        Some("6") => figures::print_fig6(&figures::fig6(&n5)),
        Some("7") => figures::print_fig7(&figures::fig7(&n6p, 30, 42)),
        Some(other) => return Err(anyhow!("unknown figure {other}")),
    }
    Ok(())
}

fn build_router(args: &Args) -> Result<(Router, Manifest)> {
    // Pin kernels BEFORE anything touches the dispatch table (the
    // MOBIRNN_FORCE_SCALAR env var is honored by detection itself).
    if args.get("force-scalar").is_some() {
        mobirnn::kernel::force_scalar();
    }
    println!(
        "kernels: {} tail={} (see --force-scalar / MOBIRNN_FORCE_SCALAR)",
        mobirnn::kernel::active().as_str(),
        mobirnn::kernel::active().tail_label()
    );
    let manifest = Manifest::load_default()?;
    let device_name = args.get_or("device", "nexus5");
    let profile = DeviceProfile::by_name(&device_name)
        .ok_or_else(|| anyhow!("unknown device {device_name:?} (nexus5|nexus6p)"))?;
    let policy = OffloadPolicy::parse(&args.get_or("policy", "cost-model"))
        .ok_or_else(|| anyhow!("bad --policy (see --help)"))?;
    let max_wait: u64 = args.get_or("max-wait-ms", "2").parse().context("--max-wait-ms")?;
    let cpu_threads: usize =
        args.get_or("cpu-threads", "4").parse().context("--cpu-threads")?;
    let max_queue: usize = args.get_or("max-queue", "256").parse().context("--max-queue")?;
    let device = DeviceState::new(profile);
    if let Some(raw) = args.get("gpu-load") {
        device.set_gpu_util(parse_util("gpu-load", raw)?);
    }
    if let Some(raw) = args.get("cpu-load") {
        device.set_cpu_util(parse_util("cpu-load", raw)?);
    }
    let runtime = Runtime::start(&manifest)?;
    let mut builder = Router::builder()
        .policy(policy)
        .device(device)
        .max_wait(Duration::from_millis(max_wait))
        .cpu_threads(cpu_threads)
        .max_queue(max_queue);
    if let Some(raw) = args.get("session-ttl-ms") {
        let ttl: u64 = raw.parse().context("--session-ttl-ms")?;
        if ttl == 0 {
            return Err(anyhow!("--session-ttl-ms must be positive"));
        }
        builder = builder.session_ttl(Duration::from_millis(ttl));
    }
    // Chaos knob (DESIGN.md §15): a fault plan wraps every matching
    // engine at build time, so the LIVE stack can be driven under
    // injected failure storms — same grammar as tests and benches.
    if let Some(plan) = args
        .get("fault-plan")
        .map(str::to_string)
        .or_else(|| std::env::var("MOBIRNN_FAULT_PLAN").ok())
    {
        let parsed = mobirnn::faults::FaultPlan::parse(&plan)
            .context("--fault-plan / MOBIRNN_FAULT_PLAN")?;
        eprintln!("fault injection ACTIVE: {plan}");
        builder = builder.fault_plan(parsed);
    }
    let router = builder.manifest(&manifest, runtime)?.build()?;
    Ok((router, manifest))
}

/// Whichever front-end `serve` picked, kept alive for the serve loop.
enum Serving {
    Threaded(Server),
    Event(EventServer),
}

impl Serving {
    fn addr(&self) -> std::net::SocketAddr {
        match self {
            Serving::Threaded(s) => s.addr(),
            Serving::Event(s) => s.addr(),
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let max_connections: usize =
        args.get_or("max-connections", "64").parse().context("--max-connections")?;
    // 0 = never time out (the historical behavior).
    let idle_ms: u64 =
        args.get_or("idle-timeout-ms", "0").parse().context("--idle-timeout-ms")?;
    let max_proto: u64 = args.get_or("proto", "3").parse().context("--proto")?;
    if !(2..=3).contains(&max_proto) {
        return Err(anyhow!("--proto must be 2 (JSON only) or 3 (binary frames)"));
    }
    // 0 = the classic thread-per-connection server.
    let io_threads: usize = args.get_or("io-threads", "0").parse().context("--io-threads")?;
    let (router, manifest) = build_router(args)?;
    let server = if io_threads > 0 {
        Serving::Event(
            EventServer::builder()
                .io_threads(io_threads)
                .max_connections(max_connections)
                .idle_timeout(Duration::from_millis(idle_ms))
                .max_proto(max_proto)
                .bind(&addr, router)?,
        )
    } else {
        Serving::Threaded(
            Server::builder()
                .max_connections(max_connections)
                .idle_timeout(Duration::from_millis(idle_ms))
                .max_proto(max_proto)
                .bind(&addr, router)?,
        )
    };
    let transport = if io_threads > 0 {
        format!("event-driven, {io_threads} io threads")
    } else {
        "thread-per-connection".to_string()
    };
    println!(
        "mobirnn serving {} on {} (policy {}, device {}, {transport}) — protocols v2..=v{max_proto}; Ctrl-C to stop",
        manifest.default_variant,
        server.addr(),
        args.get_or("policy", "cost-model"),
        args.get_or("device", "nexus5"),
    );
    // Serve forever.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_classify(args: &Args) -> Result<()> {
    let n: usize = args.get_or("n", "10").parse().context("--n")?;
    let target = match args.get("target") {
        Some(t) => {
            Some(parse_target(t).ok_or_else(|| anyhow!("unknown --target {t:?} (see --help)"))?)
        }
        None => None,
    };
    let precision = match args.get("precision") {
        Some(p) => Some(
            Precision::parse(p)
                .ok_or_else(|| anyhow!("unknown --precision {p:?} (f32|int8)"))?,
        ),
        None => None,
    };
    let (router, manifest) = build_router(args)?;
    let ds = har::HarDataset::load(manifest.path(&manifest.har_test.file))?;
    let n = n.min(ds.len());
    println!("classifying {n} windows from {} ...", manifest.har_test.file);
    let t0 = Instant::now();
    let mut correct = 0;
    for i in 0..n {
        let opts = ClassifyOptions { id: Some(i as u64), target, precision, ..Default::default() };
        let reply = router.classify_with(ds.window(i).to_vec(), opts)?;
        let gold = ds.labels[i] as usize;
        if reply.class == gold {
            correct += 1;
        }
        if i < 10 || i % 100 == 0 {
            println!(
                "  #{i:<4} pred={:<18} gold={:<18} target={:<9} sim={:.1}ms wall={:.2}ms",
                reply.label,
                har::CLASS_NAMES[gold],
                reply.target,
                reply.sim_ns as f64 / 1e6,
                reply.wall_ns as f64 / 1e6,
            );
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "\naccuracy {}/{n} = {:.1}%   wall {:.2}s ({:.1} inf/s)",
        correct,
        100.0 * correct as f64 / n as f64,
        elapsed.as_secs_f64(),
        n as f64 / elapsed.as_secs_f64()
    );
    println!("metrics: {}", router.metrics.to_json().to_json());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let man = Manifest::load_default()?;
    println!("artifacts: {:?}", man.dir);
    println!(
        "trained model: {} (test_acc {:.1}%, {} params, {} train steps)",
        man.default_variant,
        100.0 * man.train_report.test_accuracy,
        man.train_report.param_count,
        man.train_report.steps
    );
    println!("har test set: {} windows", man.har_test.n);
    println!("variants:");
    for v in &man.variants {
        println!(
            "  {:<18} batch {:<2} {}  block_h={} vmem={}KiB mxu={:.1}%",
            v.name,
            v.batch,
            if v.trained { "trained" } else { "seeded " },
            v.block_h,
            v.vmem_bytes / 1024,
            100.0 * v.mxu_utilization,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn value_flag_consumes_dash_values() {
        // `--gpu-load -0.5` must parse as key/value, not as two flags.
        let a = Args::from_parts("classify", &argv(&["--gpu-load", "-0.5"])).unwrap();
        assert_eq!(a.get("gpu-load"), Some("-0.5"));
        // (The range check then rejects it downstream.)
        assert!(parse_util("gpu-load", "-0.5").is_err());
    }

    #[test]
    fn trailing_value_flag_is_missing_value_not_bool() {
        let err = Args::from_parts("classify", &argv(&["--n"])).unwrap_err().to_string();
        assert!(err.contains("requires a value"), "{err}");
        // Also when another flag follows immediately in the old buggy
        // pattern: `--target --n 5` consumes "--n" as target's value and
        // then errors on the dangling "5" (a non-flag argument).
        let err =
            Args::from_parts("classify", &argv(&["--target", "--n", "5"])).unwrap_err().to_string();
        assert!(err.contains("unexpected argument"), "{err}");
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let err =
            Args::from_parts("classify", &argv(&["--bogus", "1"])).unwrap_err().to_string();
        assert!(err.contains("unknown flag --bogus"), "{err}");
        // A flag valid for one command is unknown for another.
        let err = Args::from_parts("figures", &argv(&["--addr", "x"])).unwrap_err().to_string();
        assert!(err.contains("unknown flag --addr"), "{err}");
    }

    #[test]
    fn bool_and_value_flags_mix() {
        let a =
            Args::from_parts("figures", &argv(&["--all"])).unwrap();
        assert_eq!(a.get("all"), Some("true"));
        let a = Args::from_parts("figures", &argv(&["--fig", "7"])).unwrap();
        assert_eq!(a.get("fig"), Some("7"));
        let a = Args::from_parts(
            "serve",
            &argv(&["--addr", "127.0.0.1:0", "--max-wait-ms", "5", "--gpu-load", "0.3"]),
        )
        .unwrap();
        assert_eq!(a.get("addr"), Some("127.0.0.1:0"));
        assert_eq!(a.get("max-wait-ms"), Some("5"));
        assert_eq!(a.get("gpu-load"), Some("0.3"));
    }

    #[test]
    fn precision_flag_parses_for_classify_only() {
        let a = Args::from_parts("classify", &argv(&["--precision", "int8"])).unwrap();
        assert_eq!(a.get("precision"), Some("int8"));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert!(Precision::parse("fp64").is_none());
        // serve takes precision per request on the wire, not as a flag.
        let err = Args::from_parts("serve", &argv(&["--precision", "int8"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag"), "{err}");
    }

    #[test]
    fn serve_admission_flags_parse() {
        let a = Args::from_parts(
            "serve",
            &argv(&["--max-queue", "32", "--max-connections", "8"]),
        )
        .unwrap();
        assert_eq!(a.get("max-queue"), Some("32"));
        assert_eq!(a.get("max-connections"), Some("8"));
        // classify takes max-queue but not the transport-level cap.
        assert!(Args::from_parts("classify", &argv(&["--max-queue", "16"])).is_ok());
        let err = Args::from_parts("classify", &argv(&["--max-connections", "8"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag"), "{err}");
    }

    #[test]
    fn fault_plan_flag_parses_on_serve_and_classify() {
        let plan = "cpu:fail_rate=0.3,latency_ms=200@p50;pjrt:hang_after=100";
        let a = Args::from_parts("serve", &argv(&["--fault-plan", plan])).unwrap();
        assert_eq!(a.get("fault-plan"), Some(plan));
        let a = Args::from_parts("classify", &argv(&["--fault-plan", plan])).unwrap();
        assert_eq!(a.get("fault-plan"), Some(plan));
        // The value must parse as a real plan, not just as a string.
        assert!(mobirnn::faults::FaultPlan::parse(plan).is_ok());
        assert!(mobirnn::faults::FaultPlan::parse("cpu:bogus=1").is_err());
    }

    #[test]
    fn serve_streaming_flags_parse() {
        let a = Args::from_parts(
            "serve",
            &argv(&["--idle-timeout-ms", "5000", "--session-ttl-ms", "60000"]),
        )
        .unwrap();
        assert_eq!(a.get("idle-timeout-ms"), Some("5000"));
        assert_eq!(a.get("session-ttl-ms"), Some("60000"));
        // Session knobs are serve-only: classify has no sessions.
        let err = Args::from_parts("classify", &argv(&["--session-ttl-ms", "1000"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag"), "{err}");
    }

    #[test]
    fn serve_transport_flags_parse() {
        let a = Args::from_parts("serve", &argv(&["--io-threads", "4", "--proto", "2"])).unwrap();
        assert_eq!(a.get("io-threads"), Some("4"));
        assert_eq!(a.get("proto"), Some("2"));
        // Transport knobs are serve-only.
        let err = Args::from_parts("classify", &argv(&["--io-threads", "2"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag"), "{err}");
        let err = Args::from_parts("classify", &argv(&["--proto", "3"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag"), "{err}");
    }

    #[test]
    fn force_scalar_flag_parses_for_serve_and_classify() {
        // Bare switch, no value — and it must not swallow the next token.
        let a = Args::from_parts("classify", &argv(&["--force-scalar", "--n", "3"])).unwrap();
        assert_eq!(a.get("force-scalar"), Some("true"));
        assert_eq!(a.get("n"), Some("3"));
        let a = Args::from_parts("serve", &argv(&["--force-scalar"])).unwrap();
        assert_eq!(a.get("force-scalar"), Some("true"));
        // figures never touches the native kernels.
        let err = Args::from_parts("figures", &argv(&["--force-scalar"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag"), "{err}");
    }

    #[test]
    fn positional_arguments_are_rejected() {
        let err = Args::from_parts("classify", &argv(&["5"])).unwrap_err().to_string();
        assert!(err.contains("unexpected argument"), "{err}");
    }

    #[test]
    fn util_range_enforced() {
        assert_eq!(parse_util("gpu-load", "0.5").unwrap(), 0.5);
        assert_eq!(parse_util("gpu-load", "0").unwrap(), 0.0);
        assert_eq!(parse_util("gpu-load", "1").unwrap(), 1.0);
        assert!(parse_util("gpu-load", "1.5").is_err());
        assert!(parse_util("gpu-load", "-0.1").is_err());
        assert!(parse_util("gpu-load", "nan").is_err());
        assert!(parse_util("gpu-load", "abc").is_err());
    }
}
