//! PJRT runtime: loads AOT HLO-text artifacts, compiles them on the PJRT
//! CPU client, and executes them from the serving hot path.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The artifact signature is
//! `logits = f(x, w0, b0, …, w_out, b_out)` with weights as HLO
//! parameters; weights are marshalled once per variant into `xla::Literal`s
//! and reused for every request (§3.2's preallocation at the XLA level).
//!
//! The `xla` wrapper types hold raw C pointers and are not `Send`, so the
//! client and all compiled executables live on ONE dedicated executor
//! thread; [`Runtime`] is a cheap, cloneable, thread-safe handle that
//! sends commands over a channel — exactly the "single hardware queue"
//! discipline a mobile GPU driver imposes, which keeps the serving
//! architecture faithful to the simulated device.

pub mod executor;

pub use executor::{Runtime, RuntimeStats};
