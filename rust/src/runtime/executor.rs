//! The PJRT executor thread and its [`Runtime`] handle.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{Manifest, VariantInfo};
use crate::lstm::weights::WeightFile;
use crate::tensor::Tensor;

enum Cmd {
    /// Compile a variant now (idempotent).
    Preload(String, mpsc::Sender<Result<(), String>>),
    /// Execute variant on `[B, T, D]` input; reply with `[B, C]` logits.
    Execute(String, Tensor, mpsc::Sender<Result<Tensor, String>>),
    Shutdown,
}

/// Cumulative executor counters (exposed on the /stats path and used by
/// the §Perf hot-path benches).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub executions: AtomicU64,
    pub exec_ns_total: AtomicU64,
    pub compiles: AtomicU64,
    pub compile_ns_total: AtomicU64,
}

/// Thread-safe handle to the PJRT executor thread.
#[derive(Clone)]
pub struct Runtime {
    tx: mpsc::Sender<Cmd>,
    stats: Arc<RuntimeStats>,
    // Keep join handle so the thread is cleanly terminated on last drop.
    joiner: Arc<Joiner>,
}

struct Joiner {
    tx: mpsc::Sender<Cmd>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    /// Weights staged as DEVICE BUFFERS once at compile time, so the hot
    /// path never re-uploads them (§Perf: literal-arg execute re-staged
    /// every weight tensor per call — ~35% of host-side latency at B=1).
    weights: Vec<xla::PjRtBuffer>,
    info: VariantInfo,
}

impl Runtime {
    /// Spawn the executor thread over `manifest`'s artifact directory.
    pub fn start(manifest: &Manifest) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let stats = Arc::new(RuntimeStats::default());
        let man = manifest.clone();
        let st = Arc::clone(&stats);
        // Fail fast if the PJRT client cannot come up: report via channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(man, rx, ready_tx, st))
            .context("spawning pjrt-executor")?;
        ready_rx
            .recv()
            .context("executor thread died during startup")?
            .map_err(|e| anyhow!(e))?;
        Ok(Self {
            tx: tx.clone(),
            stats,
            joiner: Arc::new(Joiner { tx, handle: Mutex::new(Some(handle)) }),
        })
    }

    /// Convenience: load the default artifact dir and start.
    pub fn start_default() -> Result<Self> {
        Self::start(&Manifest::load_default()?)
    }

    /// Compile `variant` now so the first request doesn't pay for it.
    pub fn preload(&self, variant: &str) -> Result<()> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Cmd::Preload(variant.to_string(), rtx))
            .map_err(|_| anyhow!("executor gone"))?;
        rrx.recv().context("executor dropped reply")?.map_err(|e| anyhow!(e))
    }

    /// Execute a variant on `x` `[B, T, D]`; returns `[B, C]` logits.
    /// Blocking; callable from any thread.
    pub fn execute(&self, variant: &str, x: Tensor) -> Result<Tensor> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Cmd::Execute(variant.to_string(), x, rtx))
            .map_err(|_| anyhow!("executor gone"))?;
        rrx.recv().context("executor dropped reply")?.map_err(|e| anyhow!(e))
    }

    pub fn stats(&self) -> &RuntimeStats {
        &self.stats
    }

    /// Mean XLA execution time over the runtime's lifetime (ns).
    pub fn mean_exec_ns(&self) -> f64 {
        let n = self.stats.executions.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.stats.exec_ns_total.load(Ordering::Relaxed) as f64 / n as f64
    }
}

fn executor_loop(
    manifest: Manifest,
    rx: mpsc::Receiver<Cmd>,
    ready_tx: mpsc::Sender<Result<(), String>>,
    stats: Arc<RuntimeStats>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready_tx.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, Compiled> = HashMap::new();

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Shutdown => break,
            Cmd::Preload(name, reply) => {
                let r = ensure_compiled(&client, &manifest, &mut cache, &name, &stats)
                    .map(|_| ())
                    .map_err(|e| format!("{e:#}"));
                let _ = reply.send(r);
            }
            Cmd::Execute(name, x, reply) => {
                let r = (|| -> Result<Tensor> {
                    ensure_compiled(&client, &manifest, &mut cache, &name, &stats)?;
                    let compiled = cache.get(&name).expect("just compiled");
                    run_compiled(compiled, &x, &stats)
                })()
                .map_err(|e| format!("{e:#}"));
                let _ = reply.send(r);
            }
        }
    }
}

fn ensure_compiled<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut HashMap<String, Compiled>,
    name: &str,
    stats: &RuntimeStats,
) -> Result<&'a Compiled> {
    if !cache.contains_key(name) {
        let info = manifest.variant(name)?.clone();
        let t0 = Instant::now();
        let hlo_path = manifest.path(&info.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing HLO {hlo_path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("XLA compile {name}: {e}"))?;

        // Marshal weights once, in manifest parameter order.
        let wf = WeightFile::load(manifest.path(&info.weights))?;
        if wf.names != info.param_names {
            return Err(anyhow!(
                "weight file order {:?} != manifest order {:?}",
                wf.names,
                info.param_names
            ));
        }
        let mut weights = Vec::with_capacity(wf.len());
        for t in wf.in_order() {
            weights.push(
                client
                    .buffer_from_host_buffer(t.data(), t.shape(), None)
                    .map_err(|e| anyhow!("staging weight buffer: {e}"))?,
            );
        }
        stats.compiles.fetch_add(1, Ordering::Relaxed);
        stats
            .compile_ns_total
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        cache.insert(name.to_string(), Compiled { exe, weights, info });
    }
    Ok(cache.get(name).expect("present"))
}

fn run_compiled(compiled: &Compiled, x: &Tensor, stats: &RuntimeStats) -> Result<Tensor> {
    let info = &compiled.info;
    let expect = [info.batch, info.seq_len, info.input_dim];
    if x.shape() != expect {
        return Err(anyhow!("input shape {:?} != variant {:?} {:?}", x.shape(), info.name, expect));
    }
    let t0 = Instant::now();
    let x_buf = compiled
        .exe
        .client()
        .buffer_from_host_buffer(x.data(), x.shape(), None)
        .map_err(|e| anyhow!("staging input buffer: {e}"))?;
    // args = [x, w0, b0, ..., w_out, b_out] — weights already on device.
    let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + compiled.weights.len());
    args.push(&x_buf);
    args.extend(compiled.weights.iter());
    let result = compiled
        .exe
        .execute_b::<&xla::PjRtBuffer>(&args)
        .map_err(|e| anyhow!("execute {}: {e}", info.name))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let logits = result.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e}"))?;
    let vals = logits.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
    stats.executions.fetch_add(1, Ordering::Relaxed);
    stats.exec_ns_total.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if vals.len() != info.batch * info.num_classes {
        return Err(anyhow!("output len {} != {}x{}", vals.len(), info.batch, info.num_classes));
    }
    Ok(Tensor::new(vec![info.batch, info.num_classes], vals))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn start_and_preload_default() {
        let Some(man) = manifest() else { return };
        let rt = Runtime::start(&man).unwrap();
        rt.preload(&man.default_variant).unwrap();
        assert_eq!(rt.stats().compiles.load(Ordering::Relaxed), 1);
        // Preload is idempotent.
        rt.preload(&man.default_variant).unwrap();
        assert_eq!(rt.stats().compiles.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn execute_shapes_and_determinism() {
        let Some(man) = manifest() else { return };
        let rt = Runtime::start(&man).unwrap();
        let v = man.variant(&man.default_variant).unwrap();
        let n = v.batch * v.seq_len * v.input_dim;
        let x = Tensor::new(
            vec![v.batch, v.seq_len, v.input_dim],
            (0..n).map(|i| (i % 17) as f32 / 17.0 - 0.5).collect(),
        );
        let a = rt.execute(&v.name, x.clone()).unwrap();
        assert_eq!(a.shape(), &[v.batch, v.num_classes]);
        let b = rt.execute(&v.name, x).unwrap();
        assert_eq!(a, b, "XLA execution must be deterministic");
        assert!(a.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn execute_rejects_wrong_shape() {
        let Some(man) = manifest() else { return };
        let rt = Runtime::start(&man).unwrap();
        let bad = Tensor::zeros(vec![1, 2, 3]);
        let err = rt.execute(&man.default_variant, bad).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn unknown_variant_errors() {
        let Some(man) = manifest() else { return };
        let rt = Runtime::start(&man).unwrap();
        assert!(rt.execute("lstm_L9_H9_B9", Tensor::zeros(vec![1, 128, 9])).is_err());
    }

    #[test]
    fn handle_clone_shares_executor() {
        let Some(man) = manifest() else { return };
        let rt = Runtime::start(&man).unwrap();
        let rt2 = rt.clone();
        rt2.preload(&man.default_variant).unwrap();
        assert_eq!(rt.stats().compiles.load(Ordering::Relaxed), 1);
    }
}
