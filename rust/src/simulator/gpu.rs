//! GPU timeline: executes a [`KernelTrace`] on the simulated mobile GPU
//! as a discrete-event simulation with display-rendering interference.
//!
//! Model (DESIGN.md §6):
//! - Launches execute **in order** (the RNN's sequential dependency and
//!   the single hardware queue of 2013-era mobile GPUs).
//! - Each launch costs `dispatch_ns` (the "function call"), then its units
//!   run in waves of `gpu_slots`; a wave takes `max_unit_flops /
//!   gpu_slot_flops_per_ns`, doubled if the kernel is divergent (§3.3).
//! - The launch additionally streams its bytes over the **shared** LPDDR
//!   bus: the post-dispatch time is `max(compute, bytes/bandwidth)` —
//!   this is the roofline that saturates Fig 5 at large hidden sizes.
//! - Without a buffer pool the launch first pays `alloc_ns` (§3.2).
//! - **Rendering preempts**: the UI renders a frame every `1/frame_rate`;
//!   under background utilization `util` the GPU is busy for
//!   `util × period` at the start of each frame (hardware-accelerated
//!   compositing has priority over app compute, §4.5). App work runs only
//!   in the free remainder of each frame and is preempted at frame
//!   boundaries; rendering also steals LPDDR bandwidth
//!   (`render_bw_contention`).

use super::des::{Clock, EventHeap};
use super::device::DeviceProfile;
use super::workunit::{KernelTrace, Launch};

/// Accounting from one simulated GPU run.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuRunResult {
    /// End-to-end latency (ns) including render-interference waits.
    pub total_ns: u64,
    /// Time spent in dispatch overhead.
    pub dispatch_ns: u64,
    /// Time spent computing waves.
    pub compute_ns: u64,
    /// Extra time where the memory bus, not the ALUs, was the limiter.
    pub mem_stall_ns: u64,
    /// Time spent waiting for the GPU behind render bursts.
    pub render_wait_ns: u64,
    /// Time spent in on-demand allocations (mem_pool=false only).
    pub alloc_ns: u64,
    pub num_launches: u64,
}

/// Busy-interval oracle for the display pipeline: frame k occupies
/// `[k·period, k·period + util·period)`.
#[derive(Debug, Clone, Copy)]
struct RenderSchedule {
    period_ns: u64,
    busy_ns: u64,
}

impl RenderSchedule {
    fn new(profile: &DeviceProfile, util: f64) -> Self {
        let period_ns = profile.frame_period_ns();
        let busy_ns = (util.clamp(0.0, 0.999) * period_ns as f64) as u64;
        Self { period_ns, busy_ns }
    }

    /// Run `need_ns` of GPU work starting no earlier than `t`, consuming
    /// only the free part of each frame (rendering has priority and
    /// preempts app compute at frame granularity). Returns
    /// `(finish_time, wait_ns)` where `wait = finish − t − need`.
    fn run_work(&self, t: u64, need_ns: u64) -> (u64, u64) {
        if self.busy_ns == 0 || need_ns == 0 {
            return (t + need_ns, 0);
        }
        let t0 = t;
        let mut t = t;
        let mut remaining = need_ns;
        loop {
            let frame = t / self.period_ns;
            let busy_end = frame * self.period_ns + self.busy_ns;
            let frame_end = (frame + 1) * self.period_ns;
            let start = t.max(busy_end);
            if start >= frame_end {
                t = frame_end;
                continue;
            }
            let avail = frame_end - start;
            if avail >= remaining {
                let finish = start + remaining;
                return (finish, finish - t0 - need_ns);
            }
            remaining -= avail;
            t = frame_end;
        }
    }
}

/// Post-dispatch execution time of one launch: compute waves vs streaming
/// the *uncached* weight fraction over the (contended) effective GPU
/// bandwidth. Returns (exec_ns, compute_ns).
fn launch_exec_ns(
    profile: &DeviceProfile,
    launch: &Launch,
    miss_fraction: f64,
    util: f64,
) -> (u64, u64) {
    let slots = profile.gpu_slots.max(1);
    let n_units = launch.units.len();
    let waves = n_units.div_ceil(slots);
    // Wave time is bounded by its largest unit; with near-even packing we
    // approximate every wave by the global max unit (exact for our traces,
    // where units within a launch differ by ≤ one column).
    let mut per_wave = launch.max_unit_flops() as f64 / profile.gpu_slot_flops_per_ns;
    if launch.divergent {
        per_wave *= 2.0; // both branch paths serialize through the SIMD lanes
    }
    let compute = (waves as f64 * per_wave) as u64;
    // Rendering steals LPDDR bandwidth proportionally to utilization.
    let bw = profile.gpu_eff_bw_bytes_per_ns
        * (1.0 - profile.render_bw_contention * util.clamp(0.0, 1.0));
    let mem = (launch.total_bytes() as f64 * miss_fraction / bw.max(1e-6)) as u64;
    (compute.max(mem), compute)
}

/// Fraction of the model's per-step weight traffic NOT retained by the
/// GPU cache across timesteps (Fig 5's saturation mechanism).
fn weight_miss_fraction(profile: &DeviceProfile, trace: &KernelTrace) -> f64 {
    let weights = trace.shape.weight_bytes_per_step() as f64;
    if weights <= 0.0 {
        return 0.0;
    }
    (1.0 - profile.gpu_weight_cache_bytes as f64 / weights).max(0.0)
}

/// Run a trace to completion on the simulated GPU under background render
/// load `util` (0..1), starting at absolute time `start_ns`.
pub fn gpu_run(profile: &DeviceProfile, trace: &KernelTrace, util: f64, start_ns: u64) -> GpuRunResult {
    let render = RenderSchedule::new(profile, util);
    let mut clock = Clock::new();
    clock.advance_to(start_ns);
    // Event heap drives the launch pipeline; with a single in-order queue
    // it holds at most one pending completion, but keeps the structure
    // ready for multi-queue devices and exercises the DES core.
    let mut events: EventHeap<usize> = EventHeap::new();
    let mut result = GpuRunResult::default();
    let miss = weight_miss_fraction(profile, trace);

    for (idx, launch) in trace.launches.iter().enumerate() {
        let alloc = if launch.needs_alloc { profile.alloc_ns } else { 0 };
        let (exec, compute) = launch_exec_ns(profile, launch, miss, util);
        let need = profile.dispatch_ns + alloc + exec;
        let (finish, wait) = render.run_work(clock.now(), need);
        clock.advance_to(finish);
        events.push(clock.now(), idx);
        // Account.
        result.render_wait_ns += wait;
        result.dispatch_ns += profile.dispatch_ns;
        result.alloc_ns += alloc;
        result.compute_ns += compute;
        result.mem_stall_ns += exec - compute;
        result.num_launches += 1;
        // Drain the completion event (in-order queue).
        let (t, done_idx) = events.pop().expect("completion pending");
        debug_assert_eq!(done_idx, idx);
        debug_assert_eq!(t, clock.now());
    }
    result.total_ns = clock.now() - start_ns;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::simulator::workunit::{build_trace, Factorization, TraceOpts};

    fn n5() -> DeviceProfile {
        DeviceProfile::nexus5()
    }

    #[test]
    fn zero_util_no_wait() {
        let t = build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let r = gpu_run(&n5(), &t, 0.0, 0);
        assert_eq!(r.render_wait_ns, 0);
        assert_eq!(r.total_ns, r.dispatch_ns + r.compute_ns + r.mem_stall_ns + r.alloc_ns);
    }

    #[test]
    fn accounting_sums_to_total() {
        let t = build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::naive());
        let r = gpu_run(&n5(), &t, 0.3, 0);
        assert_eq!(
            r.total_ns,
            r.dispatch_ns + r.compute_ns + r.mem_stall_ns + r.alloc_ns + r.render_wait_ns
        );
    }

    #[test]
    fn fine_overheads_erase_gains() {
        // §3.1: under the fine factorization, per-call overhead is a major
        // cost (>25% of runtime) and the 1-column launches waste 11/12 of
        // the slots — together making fine ≫ coarse.
        let fine = build_trace(ModelShape::default(), 1, Factorization::Fine, &TraceOpts::mobirnn());
        let coarse =
            build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let rf = gpu_run(&n5(), &fine, 0.0, 0);
        let rc = gpu_run(&n5(), &coarse, 0.0, 0);
        assert!(rf.total_ns > 10 * rc.total_ns, "fine {} vs coarse {}", rf.total_ns, rc.total_ns);
        assert!(
            rf.dispatch_ns * 4 > rf.total_ns,
            "dispatch share too small: {} of {}",
            rf.dispatch_ns,
            rf.total_ns
        );
        // Fine pays vastly more dispatch than coarse for identical math.
        assert!(rf.dispatch_ns > 50 * rc.dispatch_ns);
    }

    #[test]
    fn coarse_compute_dominates() {
        let t = build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let r = gpu_run(&n5(), &t, 0.0, 0);
        assert!(r.compute_ns + r.mem_stall_ns > r.dispatch_ns);
    }

    #[test]
    fn util_increases_latency() {
        let t = build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let mut last = 0;
        for util in [0.0, 0.25, 0.5, 0.75] {
            let r = gpu_run(&n5(), &t, util, 0);
            assert!(r.total_ns >= last);
            last = r.total_ns;
        }
        // High load should be a multiple of unloaded latency.
        let unloaded = gpu_run(&n5(), &t, 0.0, 0).total_ns;
        let loaded = gpu_run(&n5(), &t, 0.75, 0).total_ns;
        assert!(loaded > 2 * unloaded, "{loaded} vs {unloaded}");
    }

    #[test]
    fn divergence_doubles_compute() {
        let shape = ModelShape::default();
        let mut o = TraceOpts::mobirnn();
        o.divergence_free = false;
        let td = build_trace(shape, 1, Factorization::Coarse, &o);
        let tc = build_trace(shape, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let rd = gpu_run(&n5(), &td, 0.0, 0);
        let rc = gpu_run(&n5(), &tc, 0.0, 0);
        assert!(rd.compute_ns >= 2 * rc.compute_ns - 2 * tc.num_launches() as u64);
    }

    #[test]
    fn alloc_charged_only_without_pool() {
        let shape = ModelShape::default();
        let pooled = build_trace(shape, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let mut o = TraceOpts::mobirnn();
        o.mem_pool = false;
        let unpooled = build_trace(shape, 1, Factorization::Coarse, &o);
        assert_eq!(gpu_run(&n5(), &pooled, 0.0, 0).alloc_ns, 0);
        let r = gpu_run(&n5(), &unpooled, 0.0, 0);
        assert_eq!(r.alloc_ns, r.num_launches * n5().alloc_ns);
    }

    #[test]
    fn large_hidden_hits_memory_roofline() {
        // Fig 5's saturation mechanism: at H=256 the weights overflow the
        // GPU cache and streaming them — not the ALUs — bounds launches.
        let big = ModelShape::new(2, 256);
        let t = build_trace(big, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let r = gpu_run(&n5(), &t, 0.0, 0);
        // mem_stall is the EXCESS of streaming over compute; > 0 means the
        // launches have crossed the roofline (mem time ≥ compute time).
        assert!(
            r.mem_stall_ns * 10 > r.compute_ns,
            "expected memory-bound launches at H=256: stall {} compute {}",
            r.mem_stall_ns,
            r.compute_ns
        );
        // ...while the default H=32 model is fully cached: no stalls.
        let small =
            build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        assert_eq!(gpu_run(&n5(), &small, 0.0, 0).mem_stall_ns, 0);
    }

    #[test]
    fn render_schedule_preempts_at_frames() {
        let p = n5();
        let sched = RenderSchedule::new(&p, 0.5);
        let period = p.frame_period_ns();
        // At t=0 the render burst is active: work starts at busy_end.
        let (finish, wait) = sched.run_work(0, 1000);
        assert_eq!(finish, period / 2 + 1000);
        assert_eq!(wait, period / 2);
        // In the free half with room: runs immediately, no wait.
        let (f2, w2) = sched.run_work(period / 2 + 10, 1000);
        assert_eq!(f2, period / 2 + 10 + 1000);
        assert_eq!(w2, 0);
        // Near the end of a frame: does 100ns now, resumes after the next
        // burst for the remaining 900ns.
        let (f3, w3) = sched.run_work(period - 100, 1000);
        assert_eq!(f3, period + period / 2 + 900);
        assert_eq!(w3, period / 2);
    }

    #[test]
    fn long_work_survives_tiny_windows() {
        // Regression: work larger than any single free window must still
        // complete (it spans frames) — this used to loop forever at
        // util ≳ 0.9 with big models.
        let p = n5();
        let sched = RenderSchedule::new(&p, 0.95);

        let work = 10 * p.frame_period_ns(); // 10 frames of solid work
        let (finish, wait) = sched.run_work(0, work);
        assert!(finish > work);
        assert_eq!(finish - wait, work);
        // Elapsed ≈ work / free-fraction.
        let elapsed = finish as f64;
        let expected = work as f64 / 0.05;
        assert!((elapsed / expected - 1.0).abs() < 0.06, "{elapsed} vs {expected}");
    }

    #[test]
    fn full_util_still_terminates() {
        // util clamps to 0.999: progress is slow but finite.
        let p = n5();
        let sched = RenderSchedule::new(&p, 1.0);
        let (finish, _) = sched.run_work(0, 1_000_000);
        assert!(finish > 1_000_000);
    }

    #[test]
    fn start_offset_respected() {
        let t = build_trace(ModelShape::default(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let a = gpu_run(&n5(), &t, 0.0, 0);
        let b = gpu_run(&n5(), &t, 0.0, 123_456);
        assert_eq!(a.total_ns, b.total_ns);
    }
}
