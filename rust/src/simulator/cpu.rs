//! CPU execution-time model (single- and multi-threaded, paper §4.2/§4.4).
//!
//! The CPU path has no launch machinery — it is a straight roofline:
//! `time = max(flops / throughput, bytes / bandwidth) (+ spawn overhead)`.
//!
//! Background CPU load (Fig 7's "similar low/medium/high CPU loads"):
//! background tasks occupy whole cores first; our job runs on the
//! remaining free cores, or — when every core is busy — fair-share
//! time-slices on one core. The OS scheduler gives the foreground app a
//! protected share (Android keeps foreground apps responsive), so
//! degradation is gentler than the GPU's render preemption — which is
//! exactly why the paper finds CPU the better target under high load.

use crate::config::ModelShape;

use super::device::DeviceProfile;

/// Accounting from one simulated CPU run.
#[derive(Debug, Clone, Copy, Default)]
pub struct CpuRunResult {
    pub total_ns: u64,
    pub compute_ns: u64,
    pub mem_stall_ns: u64,
    pub spawn_ns: u64,
    /// Slowdown factor applied due to background load.
    pub load_factor: f64,
}

/// Fraction of nominal throughput retained under background load `util`
/// with `threads` worker threads on `cores` cores.
fn load_retention(cores: usize, threads: usize, util: f64) -> f64 {
    let util = util.clamp(0.0, 0.95);
    let busy_cores = util * cores as f64;
    let free_cores = (cores as f64 - busy_cores).max(0.0);
    let want = threads.min(cores) as f64;
    // Our threads get the free cores, floored by the foreground-priority
    // guarantee — Android's scheduler protects the focused app with
    // ~0.6 of one core even under heavy background load. Continuous in
    // `util` (no decision flapping in the cost-model policy), and this
    // gentle degradation (vs the GPU's frame-granular render preemption)
    // is why the paper finds the CPU the better target under high load
    // (§4.5 / Fig 7).
    const FOREGROUND_FLOOR: f64 = 0.6;
    free_cores.min(want).max(FOREGROUND_FLOOR) / want
}

/// f32 arithmetic throughput in the CALIBRATION FRAME. The simulator's
/// `cpu_flops_per_ns` constants were fitted so that the f32 path at
/// gain 1.0 reproduces the paper's absolute anchors (142 ms
/// single-thread 2l/32h, the 3.93×/2.83× speedups, the fig7 crossover —
/// `rust/tests/calibration.rs` asserts all of them against THIS unit).
/// Real-host kernel work (SIMD GEMMs in DESIGN.md §13, the vectorized
/// gate tail in §14) therefore recalibrates the model by renormalizing:
/// f32 stays the frame's unit and the OTHER tiers' gains are re-fit as
/// ratios against it from the measured hot-path benches. Making the
/// frame explicit keeps every paper anchor valid by construction while
/// the relative pricing tracks the hardware.
pub const F32_COMPUTE_GAIN: f64 = 1.0;

/// Arithmetic-throughput advantage of the int8 quantized path over the
/// f32 path on the same core (DESIGN.md §10, §13, §14), as a ratio
/// against [`F32_COMPUTE_GAIN`]. With the vectorized kernels the
/// widening i8×i8→i16→i32 dot product moves twice the channels per
/// vector op of the 8-lane f32 FMA — which priced int8 at ~2.2× while
/// the f32 tail still paid scalar libm `exp`/`tanh` prices. The §14
/// vectorized Padé tail removed that asymmetry (both tiers now run the
/// SAME tail kernel), collapsing the measured `native_batched_b*` vs
/// `native_quant_b*` ratio to ~1.2× across B ∈ {1..8}
/// (EXPERIMENTS.md §Perf / `BENCH_hotpath.json`): what remains is the
/// int8 GEMM's density edge minus its quantize/requantize overhead.
pub const INT8_COMPUTE_GAIN: f64 = 1.2;

/// The shared roofline body: `time = max(flops / throughput, bytes /
/// bandwidth) (+ spawn)`. Precision tiers differ ONLY in arithmetic
/// throughput (`compute_gain`) and weight-image density
/// (`bytes_per_param`: 4 for f32, 1 for packed int8 — which also sets
/// the cache-residency threshold); load behaves identically on both —
/// quantization changes per-element cost, not how the OS schedules us.
fn cpu_roofline(
    profile: &DeviceProfile,
    shape: ModelShape,
    batch: usize,
    threads: usize,
    util: f64,
    compute_gain: f64,
    bytes_per_param: u64,
) -> CpuRunResult {
    let threads = threads.max(1);
    let flops = shape.flops_per_inference() * batch as u64;
    // weight_bytes_per_step() counts f32 bytes; rescale per tier.
    let bytes = shape.weight_bytes_per_step() * shape.seq_len as u64 * bytes_per_param / 4;

    let throughput = profile.cpu_mt_flops_per_ns(threads) * compute_gain;
    let retention = load_retention(profile.cpu_cores, threads, util);
    let compute = flops as f64 / (throughput * retention);
    // Weights stream once per timestep from LPDDR; CPU caches hold the
    // small-H models entirely (32 KiB L1 / 2 MiB L2), so the memory term
    // only binds for large hidden sizes (4x later on the int8 tier,
    // whose image is one byte per parameter).
    let cacheable = shape.param_count() as u64 * bytes_per_param < 2 * 1024 * 1024;
    let mem = if cacheable { 0.0 } else { bytes as f64 / profile.bandwidth_bytes_per_ns };
    let spawn = if threads > 1 { profile.thread_spawn_ns } else { 0 };

    let body = compute.max(mem);
    CpuRunResult {
        total_ns: spawn + body as u64,
        compute_ns: compute as u64,
        mem_stall_ns: (body - compute).max(0.0) as u64,
        spawn_ns: spawn,
        load_factor: 1.0 / retention,
    }
}

/// Simulate one inference of `shape`×`batch` on the CPU with `threads`
/// worker threads under background utilization `util`.
pub fn cpu_run(
    profile: &DeviceProfile,
    shape: ModelShape,
    batch: usize,
    threads: usize,
    util: f64,
) -> CpuRunResult {
    cpu_roofline(profile, shape, batch, threads, util, F32_COMPUTE_GAIN, 4)
}

/// Simulate one inference on the int8 quantized CPU path (DESIGN.md
/// §10): the [`cpu_run`] roofline at [`INT8_COMPUTE_GAIN`]× arithmetic
/// throughput and a one-byte-per-parameter weight image.
pub fn cpu_run_int8(
    profile: &DeviceProfile,
    shape: ModelShape,
    batch: usize,
    threads: usize,
    util: f64,
) -> CpuRunResult {
    cpu_roofline(profile, shape, batch, threads, util, INT8_COMPUTE_GAIN, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n5() -> DeviceProfile {
        DeviceProfile::nexus5()
    }

    #[test]
    fn single_thread_anchor() {
        // Calibration anchor (§4.4): 2l/32h single-thread ≈ 142 ms.
        let r = cpu_run(&n5(), ModelShape::default(), 1, 1, 0.0);
        let ms = r.total_ns as f64 / 1e6;
        assert!((ms - 142.0).abs() < 15.0, "got {ms} ms");
    }

    #[test]
    fn multithread_speeds_up() {
        let s = ModelShape::default();
        let one = cpu_run(&n5(), s, 1, 1, 0.0).total_ns;
        let four = cpu_run(&n5(), s, 1, 4, 0.0).total_ns;
        assert!(four < one / 2, "4 threads {four} vs 1 thread {one}");
        // Sub-linear: speedup below 4x.
        assert!(four > one / 4);
    }

    #[test]
    fn threads_beyond_cores_no_gain() {
        let s = ModelShape::default();
        let four = cpu_run(&n5(), s, 1, 4, 0.0).total_ns;
        let sixteen = cpu_run(&n5(), s, 1, 16, 0.0).total_ns;
        assert_eq!(four, sixteen);
    }

    #[test]
    fn load_degrades_gently_single_thread() {
        // One busy core out of four leaves our single thread unaffected.
        let s = ModelShape::default();
        let idle = cpu_run(&n5(), s, 1, 1, 0.0).total_ns;
        let some = cpu_run(&n5(), s, 1, 1, 0.25).total_ns;
        assert_eq!(idle, some);
        // High load degrades but stays bounded by the foreground floor.
        let high = cpu_run(&n5(), s, 1, 1, 0.9).total_ns;
        assert!(high > idle);
        assert!(high < idle * 4);
    }

    #[test]
    fn load_hits_multithread_harder() {
        let s = ModelShape::default();
        let mt_idle = cpu_run(&n5(), s, 1, 4, 0.0).total_ns;
        let mt_high = cpu_run(&n5(), s, 1, 4, 0.8).total_ns;
        assert!(mt_high > 2 * mt_idle);
    }

    #[test]
    fn batch_scales_linearly() {
        let s = ModelShape::default();
        let b1 = cpu_run(&n5(), s, 1, 1, 0.0).total_ns;
        let b4 = cpu_run(&n5(), s, 4, 1, 0.0).total_ns;
        let ratio = b4 as f64 / b1 as f64;
        assert!((ratio - 4.0).abs() < 0.1, "{ratio}");
    }

    #[test]
    fn small_models_compute_bound_large_mem_visible() {
        let small = cpu_run(&n5(), ModelShape::default(), 1, 1, 0.0);
        assert_eq!(small.mem_stall_ns, 0);
        // H=512 exceeds the cache model; memory term participates.
        let big = cpu_run(&n5(), ModelShape::new(2, 512), 1, 1, 0.0);
        // At Java-level flop rates compute still dominates, but the term
        // must at least be computed without panic and stay consistent.
        assert_eq!(big.total_ns, big.spawn_ns + big.compute_ns.max(big.compute_ns + big.mem_stall_ns));
    }

    #[test]
    fn int8_cheaper_than_f32_per_element() {
        // The quantized path must price below the f32 path at every
        // batch size and load level — the cost-model premise of the
        // CpuQuant target (DESIGN.md §10).
        let s = ModelShape::default();
        for batch in [1usize, 2, 4, 8] {
            for util in [0.0, 0.4, 0.9] {
                let f32_ns = cpu_run(&n5(), s, batch, 1, util).total_ns;
                let int8_ns = cpu_run_int8(&n5(), s, batch, 1, util).total_ns;
                assert!(
                    int8_ns < f32_ns,
                    "b={batch} util={util}: int8 {int8_ns} !< f32 {f32_ns}"
                );
                // The gain is a throughput constant: the ratio tracks it.
                let ratio = f32_ns as f64 / int8_ns as f64;
                assert!(
                    (ratio - INT8_COMPUTE_GAIN / F32_COMPUTE_GAIN).abs() < 0.15,
                    "ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn int8_load_degrades_monotonically() {
        let s = ModelShape::default();
        let mut last = 0;
        for util in [0.0, 0.3, 0.6, 0.9] {
            let t = cpu_run_int8(&n5(), s, 1, 1, util).total_ns;
            assert!(t >= last, "util {util}: {t} < {last}");
            last = t;
        }
    }

    #[test]
    fn nexus6p_faster_cpu() {
        let s = ModelShape::default();
        let n5t = cpu_run(&n5(), s, 1, 1, 0.0).total_ns;
        let n6t = cpu_run(&DeviceProfile::nexus6p(), s, 1, 1, 0.0).total_ns;
        assert!(n6t < n5t, "§4.2: 6P CPU must be faster");
    }

    #[test]
    fn retention_bounds() {
        for cores in [1usize, 4, 8] {
            for threads in [1usize, 2, 8] {
                for util in [0.0, 0.3, 0.6, 0.95] {
                    let r = load_retention(cores, threads, util);
                    assert!(r > 0.0 && r <= 1.0, "cores={cores} threads={threads} util={util}: {r}");
                }
            }
        }
    }
}
