//! Discrete-event simulation core: a virtual clock and a stable
//! time-ordered event heap.
//!
//! Deliberately small: the GPU timeline ([`super::gpu`]) and the load
//! injectors ([`super::load`]) need exactly (a) "pop the earliest event",
//! (b) FIFO tie-breaking for equal timestamps (determinism), and (c) a
//! monotonic clock that refuses to run backwards.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual clock in nanoseconds. Monotone by construction.
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now_ns: u64,
}

impl Clock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> u64 {
        self.now_ns
    }

    /// Advance to an absolute time; panics on time travel.
    pub fn advance_to(&mut self, t_ns: u64) {
        assert!(t_ns >= self.now_ns, "clock moving backwards: {} -> {t_ns}", self.now_ns);
        self.now_ns = t_ns;
    }

    /// Advance by a delta, saturating at u64::MAX.
    pub fn advance_by(&mut self, dt_ns: u64) {
        self.now_ns = self.now_ns.saturating_add(dt_ns);
    }
}

struct HeapEntry<T> {
    time_ns: u64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ns == other.time_ns && self.seq == other.seq
    }
}
impl<T> Eq for HeapEntry<T> {}
impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first, then by
        // insertion sequence for stable FIFO ties.
        other
            .time_ns
            .cmp(&self.time_ns)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with FIFO tie-breaking.
pub struct EventHeap<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
}

impl<T> Default for EventHeap<T> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new(), next_seq: 0 }
    }
}

impl<T> EventHeap<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time_ns: u64, payload: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry { time_ns, seq, payload });
    }

    /// Pop the earliest event as (time, payload).
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|e| (e.time_ns, e.payload))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time_ns)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = EventHeap::new();
        h.push(30, "c");
        h.push(10, "a");
        h.push(20, "b");
        assert_eq!(h.pop(), Some((10, "a")));
        assert_eq!(h.pop(), Some((20, "b")));
        assert_eq!(h.pop(), Some((30, "c")));
        assert_eq!(h.pop(), None);
    }

    #[test]
    fn equal_times_are_fifo() {
        let mut h = EventHeap::new();
        for i in 0..10 {
            h.push(5, i);
        }
        for i in 0..10 {
            assert_eq!(h.pop(), Some((5, i)));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut h = EventHeap::new();
        h.push(10, 1);
        h.push(5, 0);
        assert_eq!(h.pop(), Some((5, 0)));
        h.push(7, 2);
        assert_eq!(h.pop(), Some((7, 2)));
        assert_eq!(h.pop(), Some((10, 1)));
    }

    #[test]
    fn clock_monotone() {
        let mut c = Clock::new();
        c.advance_to(100);
        c.advance_by(50);
        assert_eq!(c.now(), 150);
        c.advance_to(150); // equal is fine
    }

    #[test]
    #[should_panic]
    fn clock_rejects_backwards() {
        let mut c = Clock::new();
        c.advance_to(100);
        c.advance_to(99);
    }

    #[test]
    fn clock_saturates() {
        let mut c = Clock::new();
        c.advance_to(u64::MAX - 1);
        c.advance_by(100);
        assert_eq!(c.now(), u64::MAX);
    }

    #[test]
    fn len_tracks() {
        let mut h: EventHeap<()> = EventHeap::new();
        assert!(h.is_empty());
        h.push(1, ());
        h.push(2, ());
        assert_eq!(h.len(), 2);
        h.pop();
        assert_eq!(h.len(), 1);
    }
}
