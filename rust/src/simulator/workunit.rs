//! Work-unit IR + factorization strategies (paper Fig 2).
//!
//! One LSTM inference decomposes, per timestep and per layer, into
//! (a) the combined gate GEMM `[B, I+H] @ [I+H, 4H]` and (b) the
//! point-wise gate tail. How those ops are chopped into *work units* and
//! grouped into *launches* ("function calls to the GPU") is exactly the
//! contrast the paper draws:
//!
//! - **Fine (CUDA-style, Fig 2b)**: one work unit per output column; one
//!   launch per unit — "120 work units … leading to 120 function calls".
//! - **Coarse (RenderScript-style, Fig 2c)**: the framework packs columns
//!   into `gpu_slots` units and dispatches them as a single launch —
//!   "12 work units that compute ten vector products each".
//!
//! [`TraceOpts`] toggles the paper's §3.2–3.3 secondary optimizations so
//! ablation benches can switch them off one at a time.

use crate::config::ModelShape;

/// How GEMM columns are packed into work units and launches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Factorization {
    /// CUDA-desktop style: 1 column = 1 unit = 1 launch (paper §3.1).
    Fine,
    /// MobiRNN/RenderScript style: pack into `slots` units, 1 launch (§3.2).
    Coarse,
}

/// The §3.2/§3.3 optimization toggles (all ON = MobiRNN).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOpts {
    /// Single combined `[x;h]` GEMM vs separate input & hidden GEMMs.
    pub combined_gemm: bool,
    /// Fused point-wise tail (1 launch) vs one launch per point-wise op.
    pub fused_pointwise: bool,
    /// Preallocated, reused c/h buffers vs on-demand Allocation per launch.
    pub mem_pool: bool,
    /// Divergence-free kernels; when false, units pay a serialization
    /// penalty inside the streaming processor (§3.3).
    pub divergence_free: bool,
}

impl TraceOpts {
    /// All MobiRNN optimizations enabled (the paper's system).
    pub fn mobirnn() -> Self {
        Self { combined_gemm: true, fused_pointwise: true, mem_pool: true, divergence_free: true }
    }

    /// A naive port with none of the §3.2–3.3 optimizations.
    pub fn naive() -> Self {
        Self {
            combined_gemm: false,
            fused_pointwise: false,
            mem_pool: false,
            divergence_free: false,
        }
    }
}

/// One schedulable unit of GPU work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkUnit {
    /// Arithmetic in the unit.
    pub flops: u64,
    /// Bytes it must stream from shared memory (weights dominate).
    pub bytes: u64,
}

/// One "function call to the GPU": a dispatch carrying `units` that run
/// in waves across the device's slots.
#[derive(Debug, Clone)]
pub struct Launch {
    pub units: Vec<WorkUnit>,
    /// Unit bodies contain divergent control flow (§3.3 penalty).
    pub divergent: bool,
    /// Requires a fresh on-demand Allocation (no buffer pool).
    pub needs_alloc: bool,
}

impl Launch {
    pub fn total_flops(&self) -> u64 {
        self.units.iter().map(|u| u.flops).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.units.iter().map(|u| u.bytes).sum()
    }

    pub fn max_unit_flops(&self) -> u64 {
        self.units.iter().map(|u| u.flops).max().unwrap_or(0)
    }
}

/// The full launch sequence of one inference (sequential dependencies:
/// launches execute in order — the RNN's serial structure, §2.1).
#[derive(Debug, Clone)]
pub struct KernelTrace {
    pub launches: Vec<Launch>,
    pub shape: ModelShape,
    pub batch: usize,
}

impl KernelTrace {
    pub fn num_launches(&self) -> usize {
        self.launches.len()
    }

    pub fn total_flops(&self) -> u64 {
        self.launches.iter().map(Launch::total_flops).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.launches.iter().map(Launch::total_bytes).sum()
    }
}

/// Split one GEMM of `cols` output columns (each costing `flops_per_col`
/// / `bytes_per_col`) into launches per the strategy.
fn factorize_gemm(
    fact: Factorization,
    slots: usize,
    cols: usize,
    flops_per_col: u64,
    bytes_per_col: u64,
    opts: &TraceOpts,
) -> Vec<Launch> {
    let divergent = !opts.divergence_free;
    let needs_alloc = !opts.mem_pool;
    match fact {
        Factorization::Fine => (0..cols)
            .map(|_| Launch {
                units: vec![WorkUnit { flops: flops_per_col, bytes: bytes_per_col }],
                divergent,
                needs_alloc,
            })
            .collect(),
        Factorization::Coarse => {
            // Pack into at most `slots` units: Fig 2c's "12 work units
            // that compute ten vector products each".
            let n_units = slots.min(cols).max(1);
            let per = cols / n_units;
            let extra = cols % n_units;
            let units: Vec<WorkUnit> = (0..n_units)
                .map(|i| {
                    let c = per + usize::from(i < extra);
                    WorkUnit { flops: flops_per_col * c as u64, bytes: bytes_per_col * c as u64 }
                })
                .collect();
            vec![Launch { units, divergent, needs_alloc }]
        }
    }
}

/// Point-wise tail of one cell: 4H activations + elementwise combine.
fn pointwise_launches(
    fact: Factorization,
    slots: usize,
    hidden: usize,
    batch: usize,
    opts: &TraceOpts,
) -> Vec<Launch> {
    let divergent = !opts.divergence_free;
    let needs_alloc = !opts.mem_pool;
    // ~9 flops per hidden element (3σ + 2tanh + 2mul + 2add, amortized),
    // state bytes: read c + write c,h.
    let total_flops = (9 * hidden * batch) as u64;
    let total_bytes = (3 * hidden * batch * 4) as u64;
    let n_ops = if opts.fused_pointwise { 1 } else { 5 }; // σi,σf,σo,tanh-g,combine
    let mut out = Vec::new();
    for _ in 0..n_ops {
        let fl = total_flops / n_ops as u64;
        let by = total_bytes / n_ops as u64;
        match fact {
            Factorization::Fine => {
                // Desktop style still launches per slot-sized chunk here;
                // the dominant fine-grained cost lives in the GEMM columns.
                let n_units = slots.min(hidden).max(1);
                out.extend((0..n_units).map(|_| Launch {
                    units: vec![WorkUnit { flops: fl / n_units as u64, bytes: by / n_units as u64 }],
                    divergent,
                    needs_alloc,
                }));
            }
            Factorization::Coarse => {
                let n_units = slots.min(hidden).max(1);
                let units = (0..n_units)
                    .map(|_| WorkUnit { flops: fl / n_units as u64, bytes: by / n_units as u64 })
                    .collect();
                out.push(Launch { units, divergent, needs_alloc });
            }
        }
    }
    out
}

/// Build the launch trace of one inference.
///
/// `slots` is read from the Nexus-5 profile's 12 by the caller via
/// [`build_trace_with_slots`]; this convenience uses 12 (the paper's
/// "scheduled twelve at a time").
pub fn build_trace(shape: ModelShape, batch: usize, fact: Factorization, opts: &TraceOpts) -> KernelTrace {
    build_trace_with_slots(shape, batch, fact, opts, 12)
}

/// Build the launch trace with an explicit slot width (device-specific).
pub fn build_trace_with_slots(
    shape: ModelShape,
    batch: usize,
    fact: Factorization,
    opts: &TraceOpts,
    slots: usize,
) -> KernelTrace {
    let mut launches = Vec::new();
    let h = shape.hidden;
    for _t in 0..shape.seq_len {
        let mut in_dim = shape.input_dim;
        for _l in 0..shape.num_layers {
            let cols = 4 * h;
            if opts.combined_gemm {
                // One [B, I+H] @ [I+H, 4H] GEMM.
                let fpc = (2 * (in_dim + h) * batch) as u64;
                let bpc = ((in_dim + h) * 4) as u64; // one weight column
                launches.extend(factorize_gemm(fact, slots, cols, fpc, bpc, opts));
            } else {
                // Separate input and hidden GEMMs (pre-§3.3 form):
                // same math, one extra pass + one extra dispatch set.
                let fpc_x = (2 * in_dim * batch) as u64;
                let bpc_x = (in_dim * 4) as u64;
                let fpc_h = (2 * h * batch) as u64;
                let bpc_h = (h * 4) as u64;
                launches.extend(factorize_gemm(fact, slots, cols, fpc_x, bpc_x, opts));
                launches.extend(factorize_gemm(fact, slots, cols, fpc_h, bpc_h, opts));
            }
            launches.extend(pointwise_launches(fact, slots, h, batch, opts));
            in_dim = h;
        }
    }
    // Classifier head: one small GEMM launch.
    let fpc = (2 * h * batch) as u64;
    let bpc = (h * 4) as u64;
    launches.extend(factorize_gemm(fact, slots, shape.num_classes, fpc, bpc, opts));
    KernelTrace { launches, shape, batch }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_shape() -> ModelShape {
        ModelShape::default()
    }

    #[test]
    fn fine_has_one_launch_per_column() {
        // Paper §3.1's example: a gate GEMM with 4H=128 columns issues 128
        // "function calls" per layer-step under the fine factorization.
        let t = build_trace(default_shape(), 1, Factorization::Fine, &TraceOpts::mobirnn());
        // per layer-step: 128 gemm launches + 12 pointwise; 2 layers, 128 steps
        let per_step_layer = 128 + 12;
        let expected = 128 * 2 * per_step_layer + 6; // + head (6 cols fine)
        assert_eq!(t.num_launches(), expected);
    }

    #[test]
    fn coarse_has_two_launches_per_cell() {
        let t = build_trace(default_shape(), 1, Factorization::Coarse, &TraceOpts::mobirnn());
        // per layer-step: 1 gemm + 1 fused pointwise; + 1 head
        assert_eq!(t.num_launches(), 128 * 2 * 2 + 1);
    }

    #[test]
    fn coarse_packs_into_slot_units() {
        // Fig 2c: the paper's 32x120 example -> 12 units of 10 columns.
        let shape = ModelShape { num_layers: 1, hidden: 30, input_dim: 2, seq_len: 1, num_classes: 6 };
        let t = build_trace(shape, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let gemm = &t.launches[0];
        assert_eq!(gemm.units.len(), 12);
        // 120 columns over 12 units = 10 each, perfectly even
        let fl: Vec<u64> = gemm.units.iter().map(|u| u.flops).collect();
        assert!(fl.iter().all(|&f| f == fl[0]));
    }

    #[test]
    fn uneven_columns_distribute_within_one() {
        let shape = ModelShape { num_layers: 1, hidden: 25, input_dim: 2, seq_len: 1, num_classes: 6 };
        // 100 columns over 12 units: 4 units of 9, 8 of 8.
        let t = build_trace(shape, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let sizes: Vec<u64> = t.launches[0].units.iter().map(|u| u.flops).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        let fpc = 2 * (2 + 25) as u64;
        assert!(max - min <= fpc, "unit imbalance > 1 column");
    }

    #[test]
    fn total_flops_invariant_under_factorization() {
        // Chopping differently must never change the arithmetic performed.
        let s = default_shape();
        let fine = build_trace(s, 1, Factorization::Fine, &TraceOpts::mobirnn());
        let coarse = build_trace(s, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        assert_eq!(fine.total_flops(), coarse.total_flops());
    }

    #[test]
    fn split_gemm_costs_more_dispatches_same_flops_order() {
        let s = default_shape();
        let combined = build_trace(s, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let mut o = TraceOpts::mobirnn();
        o.combined_gemm = false;
        let split = build_trace(s, 1, Factorization::Coarse, &o);
        assert!(split.num_launches() > combined.num_launches());
        // split performs the same MACs (x-part + h-part = combined part)
        assert_eq!(split.total_flops(), combined.total_flops());
    }

    #[test]
    fn unfused_pointwise_multiplies_launches() {
        let s = default_shape();
        let mut o = TraceOpts::mobirnn();
        o.fused_pointwise = false;
        let unfused = build_trace(s, 1, Factorization::Coarse, &o);
        let fused = build_trace(s, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        assert_eq!(unfused.num_launches(), 128 * 2 * 6 + 1); // 1 gemm + 5 pw
        assert!(unfused.num_launches() > fused.num_launches());
    }

    #[test]
    fn naive_opts_flag_launches() {
        let s = default_shape();
        let t = build_trace(s, 1, Factorization::Coarse, &TraceOpts::naive());
        assert!(t.launches.iter().all(|l| l.divergent && l.needs_alloc));
        let t2 = build_trace(s, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        assert!(t2.launches.iter().all(|l| !l.divergent && !l.needs_alloc));
    }

    #[test]
    fn batch_scales_flops_not_launches() {
        let s = default_shape();
        let b1 = build_trace(s, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let b4 = build_trace(s, 4, Factorization::Coarse, &TraceOpts::mobirnn());
        assert_eq!(b1.num_launches(), b4.num_launches());
        assert!(b4.total_flops() > 3 * b1.total_flops());
    }

    #[test]
    fn bytes_track_weight_streaming() {
        // Per-inference weight traffic ~= weight_bytes_per_step * seq_len.
        let s = default_shape();
        let t = build_trace(s, 1, Factorization::Coarse, &TraceOpts::mobirnn());
        let weights = s.weight_bytes_per_step() * s.seq_len as u64;
        let total = t.total_bytes();
        // Within [90%, 200%]: launches stream the weight matrices (biases
        // ride along with dispatch, state traffic is small).
        assert!(total * 10 > weights * 9, "weights must dominate: {total} vs {weights}");
        assert!(total < 2 * weights, "state traffic should not dominate");
    }

    #[test]
    fn custom_slot_width_respected() {
        let s = default_shape();
        let t = build_trace_with_slots(s, 1, Factorization::Coarse, &TraceOpts::mobirnn(), 16);
        assert_eq!(t.launches[0].units.len(), 16);
    }
}
