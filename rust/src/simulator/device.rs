//! Device profiles — the calibrated stand-ins for the paper's phones.
//!
//! # Calibration method (DESIGN.md §6)
//!
//! Anchors taken from the paper's text:
//! - Nexus 5, 2l/32h: **142 ms** per inference single-thread CPU (§4.4
//!   "single thread CPU time is 142ms on average"), **~36 ms** MobiRNN
//!   GPU (3.93× speedup, §4.2; the quoted "29ms" is the best case).
//! - CUDA-style fine factorization: **up to 4× slower** than CPU (§3.1).
//! - "120 work units are scheduled twelve at a time" (§3.1) → 12 GPU
//!   slots on Nexus 5 (Adreno 330).
//! - Nexus 6P: octa-core (2× cores), 25.6 GB/s (2× bandwidth), GPU
//!   "comparable" → CPU-side ~1.4× faster single-core, GPU equal →
//!   2.83× speedup (§4.2).
//!
//! Derived constants (solved from the anchors, see the worked numbers in
//! each field's doc):
//! - `cpu_flops_per_ns`: 2l/32h is ~3.52 MFLOP/inference; 142 ms ⇒
//!   ~0.0248 flop/ns (≈25 MFLOP/s — the paper's Java/Dalvik
//!   single-thread implementation, not native SIMD).
//! - `dispatch_ns` (6 µs) and `gpu_slot_flops_per_ns` (0.00914) solve the
//!   2×2 system {coarse = 36.1 ms (3.93×), fine ≈ 4× slower than CPU}:
//!   fine issues one launch per column AND wastes 11/12 slots per wave,
//!   so it pays 35 840 dispatches (~215 ms) plus 1/12-occupancy compute
//!   (~377 ms) ⇒ 592 ms ≈ 4.2× slower ✓; coarse issues 2 launches per
//!   layer-step at full occupancy ⇒ 36.1 ms ✓.
//! - `gpu_eff_bw_bytes_per_ns` (0.18) + `gpu_weight_cache_bytes` (256 KiB):
//!   models whose weights fit the GPU cache (H≤64) are compute-bound;
//!   H≥128 streams the uncached weight fraction each timestep and the
//!   memory term overtakes compute — reproducing Fig 5's rise-then-
//!   saturate: speedups 3.84/3.93/3.95 over layers, 3.93/4.19/4.36/3.95
//!   over hidden 32/64/128/256.
//!
//! These are *simulator* constants: they reproduce the paper's latency
//! shapes and ratios, not Adreno microarchitecture.



/// A simulated phone SoC.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,

    // --- CPU ---
    /// Physical cores available to app threads.
    pub cpu_cores: usize,
    /// Effective single-thread throughput of the interpreter-style
    /// implementation the paper benchmarks (flop/ns).
    pub cpu_flops_per_ns: f64,
    /// Multithreading efficiency: per-core fraction retained when all
    /// cores are busy (sync + LLC contention).
    pub cpu_mt_efficiency: f64,
    /// One-time cost to fan work out to a thread pool (ns).
    pub thread_spawn_ns: u64,

    // --- GPU ---
    /// Parallel execution slots (wavefront width the RS runtime fills).
    pub gpu_slots: usize,
    /// Effective per-slot throughput for small RS kernels (flop/ns).
    pub gpu_slot_flops_per_ns: f64,
    /// Driver cost per kernel launch / "function call" (ns).
    pub dispatch_ns: u64,
    /// Cost of an on-demand Allocation when buffers are NOT pooled (ns);
    /// only charged when `TraceOpts.mem_pool == false` (§3.2 ablation).
    pub alloc_ns: u64,

    // --- Shared memory system ---
    /// LPDDR bandwidth shared by CPU and GPU (bytes/ns; 12.8 GB/s = 12.8).
    /// Peak spec; the CPU cache model keys off it for very large models.
    pub bandwidth_bytes_per_ns: f64,
    /// *Effective* GPU streaming bandwidth for RenderScript kernels
    /// reading weights from LPDDR (bytes/ns). Far below peak: uncoalesced
    /// per-unit access, no prefetch (Fig 5's "takes longer to load the
    /// parameters").
    pub gpu_eff_bw_bytes_per_ns: f64,
    /// GPU-side cache (L2 + texture) that retains weights across
    /// timesteps. Models whose weights fit stream ~nothing per step;
    /// larger models pay the uncached fraction — this is the mechanism
    /// behind Fig 5's hidden-unit saturation.
    pub gpu_weight_cache_bytes: u64,
    /// Fraction of effective GPU bandwidth stolen per unit of render
    /// utilization (the compositor shares the LPDDR bus, §4.5).
    pub render_bw_contention: f64,

    // --- Display pipeline (background GPU load, Fig 7) ---
    /// UI frame rate; rendering occupies the GPU `util × period` per frame.
    pub frame_rate_hz: f64,
}

impl DeviceProfile {
    /// Nexus 5 (2013): quad Krait 400, Adreno 330, 12.8 GB/s LPDDR3.
    pub fn nexus5() -> Self {
        Self {
            name: "nexus5".into(),
            cpu_cores: 4,
            cpu_flops_per_ns: 0.0248,
            cpu_mt_efficiency: 0.78,
            thread_spawn_ns: 120_000,
            gpu_slots: 12,
            gpu_slot_flops_per_ns: 0.00914,
            dispatch_ns: 6_000,
            alloc_ns: 30_000,
            bandwidth_bytes_per_ns: 12.8,
            gpu_eff_bw_bytes_per_ns: 0.18,
            gpu_weight_cache_bytes: 256 * 1024,
            render_bw_contention: 0.5,
            frame_rate_hz: 60.0,
        }
    }

    /// Nexus 6P (2015): octa Kryo-ish (paper: "twice the cores"), Adreno
    /// 430 ("GPU comparable"), 25.6 GB/s LPDDR4.
    pub fn nexus6p() -> Self {
        Self {
            name: "nexus6p".into(),
            cpu_cores: 8,
            cpu_flops_per_ns: 0.0248 * 1.39, // newer core, same Java stack
            cpu_mt_efficiency: 0.74,         // big.LITTLE heterogeneity tax
            thread_spawn_ns: 100_000,
            gpu_slots: 16,
            gpu_slot_flops_per_ns: 0.00686, // comparable net GPU perf (16 slots)
            dispatch_ns: 6_000,
            alloc_ns: 28_000,
            bandwidth_bytes_per_ns: 25.6,
            gpu_eff_bw_bytes_per_ns: 0.36,   // 2x bus -> 2x effective
            gpu_weight_cache_bytes: 512 * 1024,
            render_bw_contention: 0.5,
            frame_rate_hz: 60.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "nexus5" => Some(Self::nexus5()),
            "nexus6p" => Some(Self::nexus6p()),
            _ => None,
        }
    }

    /// Aggregate multi-threaded CPU throughput with `threads` workers.
    pub fn cpu_mt_flops_per_ns(&self, threads: usize) -> f64 {
        let t = threads.min(self.cpu_cores) as f64;
        if threads <= 1 {
            self.cpu_flops_per_ns
        } else {
            self.cpu_flops_per_ns * t * self.cpu_mt_efficiency
        }
    }

    /// Display frame period in ns.
    pub fn frame_period_ns(&self) -> u64 {
        (1e9 / self.frame_rate_hz) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_resolve_by_name() {
        assert_eq!(DeviceProfile::by_name("nexus5").unwrap().cpu_cores, 4);
        assert_eq!(DeviceProfile::by_name("nexus6p").unwrap().cpu_cores, 8);
        assert!(DeviceProfile::by_name("pixel9000").is_none());
    }

    #[test]
    fn paper_hardware_relationships() {
        let n5 = DeviceProfile::nexus5();
        let n6p = DeviceProfile::nexus6p();
        // §4.2: 6P has twice the cores and twice the bandwidth.
        assert_eq!(n6p.cpu_cores, 2 * n5.cpu_cores);
        assert!((n6p.bandwidth_bytes_per_ns / n5.bandwidth_bytes_per_ns - 2.0).abs() < 1e-9);
        // §3.1: Nexus 5 schedules "twelve at a time".
        assert_eq!(n5.gpu_slots, 12);
        // 6P CPU is faster single-core; GPUs are comparable.
        assert!(n6p.cpu_flops_per_ns > n5.cpu_flops_per_ns);
        let n5_gpu = n5.gpu_slots as f64 * n5.gpu_slot_flops_per_ns;
        let n6p_gpu = n6p.gpu_slots as f64 * n6p.gpu_slot_flops_per_ns;
        assert!((n6p_gpu / n5_gpu - 1.0).abs() < 0.25, "GPUs should be comparable");
    }

    #[test]
    fn mt_throughput_scales_but_sublinearly() {
        let p = DeviceProfile::nexus5();
        let one = p.cpu_mt_flops_per_ns(1);
        let four = p.cpu_mt_flops_per_ns(4);
        assert!(four > 2.5 * one);
        assert!(four < 4.0 * one);
        // more threads than cores: no extra throughput
        assert_eq!(p.cpu_mt_flops_per_ns(16), p.cpu_mt_flops_per_ns(4));
    }

    #[test]
    fn frame_period() {
        assert_eq!(DeviceProfile::nexus5().frame_period_ns(), 16_666_666);
    }
}
