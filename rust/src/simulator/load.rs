//! Background-load levels and trace generation (paper §4.5 / Fig 7).
//!
//! The paper buckets GPU utilization into low (<30%), medium (30–50%)
//! and high (>70%) using ADB sampling. [`LoadLevel`] reproduces those
//! buckets; [`LoadTrace`] draws a jittered utilization sample per
//! inference so repeated runs show realistic spread (the dots in Fig 7),
//! deterministically from a seed.

use crate::util::Rng;

/// The paper's three GPU-load buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoadLevel {
    /// < 30% utilization.
    Low,
    /// 30–50% utilization.
    Medium,
    /// > 70% utilization.
    High,
}

impl LoadLevel {
    pub const ALL: [LoadLevel; 3] = [LoadLevel::Low, LoadLevel::Medium, LoadLevel::High];

    /// Bucket midpoint used for headline numbers.
    pub fn nominal_util(self) -> f64 {
        match self {
            LoadLevel::Low => 0.15,
            LoadLevel::Medium => 0.40,
            LoadLevel::High => 0.78,
        }
    }

    /// Sampling range (min, max) within the bucket.
    pub fn util_range(self) -> (f64, f64) {
        match self {
            LoadLevel::Low => (0.02, 0.30),
            LoadLevel::Medium => (0.30, 0.50),
            LoadLevel::High => (0.70, 0.92),
        }
    }

    /// Classify a measured utilization into the paper's buckets
    /// (the 50–70% gap goes to Medium's upper shoulder, as the paper's
    /// methodology leaves it unassigned).
    pub fn classify(util: f64) -> LoadLevel {
        if util < 0.30 {
            LoadLevel::Low
        } else if util <= 0.70 {
            LoadLevel::Medium
        } else {
            LoadLevel::High
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            LoadLevel::Low => "low (<30%)",
            LoadLevel::Medium => "medium (30-50%)",
            LoadLevel::High => "high (>70%)",
        }
    }
}

/// Deterministic per-inference utilization sampler within a bucket.
#[derive(Debug, Clone)]
pub struct LoadTrace {
    level: LoadLevel,
    rng: Rng,
}

impl LoadTrace {
    pub fn new(level: LoadLevel, seed: u64) -> Self {
        Self { level, rng: Rng::new(seed) }
    }

    pub fn level(&self) -> LoadLevel {
        self.level
    }

    /// Next sampled utilization in the bucket's range.
    pub fn sample(&mut self) -> f64 {
        let (lo, hi) = self.level.util_range();
        lo + (hi - lo) * self.rng.next_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_in_range() {
        for level in LoadLevel::ALL {
            let (lo, hi) = level.util_range();
            let nom = level.nominal_util();
            assert!(nom >= lo && nom <= hi, "{level:?}");
        }
    }

    #[test]
    fn classify_matches_paper_buckets() {
        assert_eq!(LoadLevel::classify(0.1), LoadLevel::Low);
        assert_eq!(LoadLevel::classify(0.29), LoadLevel::Low);
        assert_eq!(LoadLevel::classify(0.35), LoadLevel::Medium);
        assert_eq!(LoadLevel::classify(0.75), LoadLevel::High);
        assert_eq!(LoadLevel::classify(0.95), LoadLevel::High);
    }

    #[test]
    fn samples_stay_in_bucket() {
        for level in LoadLevel::ALL {
            let mut trace = LoadTrace::new(level, 99);
            let (lo, hi) = level.util_range();
            for _ in 0..1000 {
                let u = trace.sample();
                assert!(u >= lo && u < hi, "{level:?}: {u}");
            }
        }
    }

    #[test]
    fn trace_deterministic() {
        let mut a = LoadTrace::new(LoadLevel::Medium, 5);
        let mut b = LoadTrace::new(LoadLevel::Medium, 5);
        for _ in 0..50 {
            assert_eq!(a.sample(), b.sample());
        }
    }

    #[test]
    fn levels_ordered() {
        assert!(LoadLevel::Low.nominal_util() < LoadLevel::Medium.nominal_util());
        assert!(LoadLevel::Medium.nominal_util() < LoadLevel::High.nominal_util());
    }
}
