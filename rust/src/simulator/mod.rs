//! Discrete-event mobile-SoC simulator — the hardware substitute for the
//! paper's Nexus 5 / Nexus 6P testbed (DESIGN.md §2).
//!
//! Every figure in the paper is a *latency shape* produced by four
//! mechanisms, and the simulator models exactly those four:
//!
//! 1. **Dispatch overhead per GPU "function call"** (`device::dispatch_ns`)
//!    — the paper's §3.1 observation that a CUDA-style factorization makes
//!    one call per work unit ("120 function calls to the GPU") while
//!    RenderScript makes one call per kernel containing many units.
//! 2. **Limited parallel slots** (`device::gpu_slots`) — "scheduled twelve
//!    at a time" fixes Nexus 5 at 12; units within a launch run in waves.
//! 3. **Shared memory bandwidth** (`device::bandwidth_bytes_per_ns`) —
//!    CPU and GPU share LPDDR on a phone SoC; weight streaming per
//!    timestep caps GPU benefit as hidden size grows (Fig 5 saturation).
//! 4. **Interference** (`load`) — UI rendering preempts the GPU at frame
//!    granularity (Fig 7); background CPU tasks occupy cores.
//!
//! Calibration anchors and tolerances are documented in [`device`] and
//! asserted by `rust/tests/calibration.rs`.

pub mod cpu;
pub mod des;
pub mod device;
pub mod gpu;
pub mod load;
pub mod workunit;

use crate::config::ModelShape;

pub use cpu::{cpu_run, cpu_run_int8, CpuRunResult, F32_COMPUTE_GAIN, INT8_COMPUTE_GAIN};
pub use des::{Clock, EventHeap};
pub use device::DeviceProfile;
pub use gpu::{gpu_run, GpuRunResult};
pub use load::LoadLevel;
pub use workunit::{build_trace, build_trace_with_slots, Factorization, KernelTrace, Launch, TraceOpts, WorkUnit};

/// Where an inference runs (the coordinator's offload decision).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    /// Mobile GPU with the given factorization strategy.
    Gpu(Factorization),
    /// Single-threaded CPU (the paper's baseline bars).
    CpuSingle,
    /// Multi-threaded CPU with `n` threads (paper §4.4).
    CpuMulti(usize),
    /// Single-threaded CPU on the int8 quantized path (DESIGN.md §10):
    /// same roofline as [`Target::CpuSingle`] with int8 arithmetic
    /// throughput and a quarter of the weight traffic. Entered only by
    /// explicit request (`precision: int8`) — the offload policy never
    /// trades precision for latency on its own.
    CpuQuant,
}

/// Simulated latency of ONE inference of `shape` at `batch` on `target`
/// under background utilization `util` (0..1). Returns nanoseconds.
///
/// This is the single entry point the coordinator, figures and benches
/// use; it dispatches to the GPU DES or the CPU analytical model.
pub fn simulate_inference(
    profile: &DeviceProfile,
    shape: ModelShape,
    batch: usize,
    target: Target,
    util: f64,
) -> u64 {
    match target {
        Target::Gpu(fact) => {
            let trace =
                build_trace_with_slots(shape, batch, fact, &TraceOpts::mobirnn(), profile.gpu_slots);
            gpu_run(profile, &trace, util, 0).total_ns
        }
        Target::CpuSingle => cpu_run(profile, shape, batch, 1, util).total_ns,
        Target::CpuMulti(n) => cpu_run(profile, shape, batch, n, util).total_ns,
        Target::CpuQuant => cpu_run_int8(profile, shape, batch, 1, util).total_ns,
    }
}

/// Simulated latency with explicit trace options (ablation entry point).
pub fn simulate_gpu_with_opts(
    profile: &DeviceProfile,
    shape: ModelShape,
    batch: usize,
    fact: Factorization,
    opts: &TraceOpts,
    util: f64,
) -> u64 {
    let trace = build_trace_with_slots(shape, batch, fact, opts, profile.gpu_slots);
    gpu_run(profile, &trace, util, 0).total_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;

    #[test]
    fn gpu_coarse_beats_cpu_on_default_model() {
        // The paper's headline direction (Fig 4): MobiRNN (coarse) GPU is
        // multiple times faster than single-thread CPU on Nexus 5.
        let p = DeviceProfile::nexus5();
        let shape = ModelShape::default();
        let gpu = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Coarse), 0.0);
        let cpu = simulate_inference(&p, shape, 1, Target::CpuSingle, 0.0);
        assert!(gpu < cpu, "gpu {gpu} !< cpu {cpu}");
    }

    #[test]
    fn gpu_fine_loses_to_cpu() {
        // Fig 3: CUDA-style factorization on a mobile GPU is SLOWER than CPU.
        let p = DeviceProfile::nexus5();
        let shape = ModelShape::default();
        let gpu = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Fine), 0.0);
        let cpu = simulate_inference(&p, shape, 1, Target::CpuSingle, 0.0);
        assert!(gpu > cpu, "fine gpu {gpu} should lose to cpu {cpu}");
    }

    #[test]
    fn multithread_between_single_and_gpu() {
        // Fig 6: MT-CPU recovers most of the GPU benefit.
        let p = DeviceProfile::nexus5();
        let shape = ModelShape::default();
        let single = simulate_inference(&p, shape, 1, Target::CpuSingle, 0.0);
        let multi = simulate_inference(&p, shape, 1, Target::CpuMulti(4), 0.0);
        let gpu = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Coarse), 0.0);
        assert!(multi < single);
        assert!(gpu < multi);
    }

    #[test]
    fn load_increases_latency_monotonically() {
        let p = DeviceProfile::nexus5();
        let shape = ModelShape::default();
        let mut last = 0;
        for util in [0.0, 0.2, 0.4, 0.6, 0.8] {
            let t = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Coarse), util);
            assert!(t >= last, "util {util}: {t} < {last}");
            last = t;
        }
    }
}
