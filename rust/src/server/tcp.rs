//! Threaded TCP transport for the JSON-lines protocol (v2).
//!
//! The transport is deliberately thin: it reads lines, hands them to
//! [`protocol::handle_line`], writes back the typed [`Response`]'s wire
//! form, and closes when the response says so ([`Response::Bye`]).
//!
//! Connection discipline (DESIGN.md §9): every handler thread is
//! TRACKED — [`Server::stop`] force-closes the live sockets and joins
//! every `mobirnn-conn` thread, so stop is clean under load — and the
//! acceptor caps live connections at [`ServerBuilder::max_connections`],
//! refusing the overflow with a typed `overloaded` error line.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{Precision, Router};
use crate::json::{FromValue, ToValue, Value};
use crate::server::protocol::{self, ClassifyOutcome, ErrorCode, Request, Response};

/// One tracked connection: the handle to join, plus a clone of the
/// stream so `stop` can force the handler's blocking read to return.
struct ConnSlot {
    stream: TcpStream,
    handle: std::thread::JoinHandle<()>,
}

/// Transport knobs; build with [`Server::builder`].
pub struct ServerBuilder {
    max_connections: usize,
    idle_timeout: Option<std::time::Duration>,
}

impl ServerBuilder {
    pub fn new() -> Self {
        Self { max_connections: 64, idle_timeout: None }
    }

    /// Cap on concurrently served connections (default 64). Clients
    /// beyond the cap receive one typed `overloaded` error line and are
    /// disconnected — bounded admission at the transport layer, the
    /// sibling of the scheduler's `max_queue`.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n.max(1);
        self
    }

    /// Close a connection that sends nothing for this long (default:
    /// never). Streaming clients hold connections open between chunks;
    /// without a bound, an abandoned stream pins one `mobirnn-conn`
    /// thread (and one `max_connections` slot) forever. Expiry is clean:
    /// the handler writes one `bye` line, then closes. Zero disables.
    pub fn idle_timeout(mut self, d: std::time::Duration) -> Self {
        self.idle_timeout = (!d.is_zero()).then_some(d);
        self
    }

    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// `router` until stopped.
    pub fn bind(self, addr: &str, router: Router) -> Result<Server> {
        Server::start(addr, router, self.max_connections, self.idle_timeout)
    }
}

impl Default for ServerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A running server; drop or call [`Server::stop`] to shut down.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicU64>,
    refused: Arc<AtomicU64>,
    conns: Arc<Mutex<Vec<ConnSlot>>>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start configuring a server (connection cap etc.).
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// [`ServerBuilder::bind`] with default knobs.
    pub fn bind(addr: &str, router: Router) -> Result<Self> {
        Self::builder().bind(addr, router)
    }

    fn start(
        addr: &str,
        router: Router,
        max_connections: usize,
        idle_timeout: Option<std::time::Duration>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let refused = Arc::new(AtomicU64::new(0));
        let conns: Arc<Mutex<Vec<ConnSlot>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = Arc::clone(&stop);
        let accepted2 = Arc::clone(&connections);
        let refused2 = Arc::clone(&refused);
        let conns2 = Arc::clone(&conns);
        // Poll-accept so the stop flag is honored promptly.
        listener.set_nonblocking(true)?;
        let acceptor = std::thread::Builder::new()
            .name("mobirnn-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // Reap finished handlers so the cap counts
                            // live connections only.
                            let live = {
                                let mut conns = conns2.lock().unwrap();
                                conns.retain(|c| !c.handle.is_finished());
                                conns.len()
                            };
                            if live >= max_connections {
                                refused2.fetch_add(1, Ordering::Relaxed);
                                refuse_connection(stream, max_connections);
                                continue;
                            }
                            // An untrackable connection would be
                            // invisible to the cap and un-joinable by
                            // stop(): refuse it rather than leak it.
                            let peer = match stream.try_clone() {
                                Ok(p) => p,
                                Err(_) => {
                                    refused2.fetch_add(1, Ordering::Relaxed);
                                    refuse_connection(stream, max_connections);
                                    continue;
                                }
                            };
                            accepted2.fetch_add(1, Ordering::Relaxed);
                            let router = router.clone();
                            let spawned = std::thread::Builder::new()
                                .name("mobirnn-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(stream, router, idle_timeout);
                                });
                            if let Ok(handle) = spawned {
                                conns2
                                    .lock()
                                    .unwrap()
                                    .push(ConnSlot { stream: peer, handle });
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning acceptor")?;
        Ok(Self { addr: local, stop, connections, refused, conns, acceptor: Some(acceptor) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn connections_accepted(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    /// Connections turned away at the `max_connections` cap.
    pub fn connections_refused(&self) -> u64 {
        self.refused.load(Ordering::Relaxed)
    }

    /// Stop accepting, force-close every live connection, and join all
    /// handler threads. Previously only the acceptor was joined, leaking
    /// live `mobirnn-conn` threads past stop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let slots: Vec<ConnSlot> = std::mem::take(&mut *self.conns.lock().unwrap());
        for slot in slots {
            // Shutdown unblocks the handler's read (EOF/error); a
            // NotConnected error just means it already exited.
            let _ = slot.stream.shutdown(std::net::Shutdown::Both);
            let _ = slot.handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Tell an over-cap client why it is being dropped: one typed error
/// line, a write-side FIN, a brief drain of whatever the client already
/// sent, then close. The drain matters: dropping a socket with unread
/// bytes in the receive buffer sends RST, which can destroy the error
/// line before the client reads it.
fn refuse_connection(mut stream: TcpStream, max_connections: usize) {
    let resp = Response::Error {
        id: None,
        code: ErrorCode::Overloaded,
        message: format!("server at max_connections={max_connections}"),
    };
    let mut line = resp.to_value().to_json();
    line.push('\n');
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(50)));
    let mut sink = [0u8; 512];
    for _ in 0..8 {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    router: Router,
    idle_timeout: Option<std::time::Duration>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    if let Some(d) = idle_timeout {
        stream.set_read_timeout(Some(d)).ok();
    }
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client hung up.
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle past the timeout (a stalled mid-line write counts
                // too): one `bye` line, then a clean close, so the
                // thread and its max_connections slot come back.
                let mut out = Response::Bye.to_value().to_json();
                out.push('\n');
                let _ = writer.write_all(out.as_bytes());
                break;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = protocol::handle_line(&router, line.trim_end());
        let close = matches!(resp, Response::Bye);
        let mut out = resp.to_value().to_json();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
        if close {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, examples and the CLI. Speaks the
/// typed protocol: requests go out as [`Request`], replies come back as
/// [`Response`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Send one typed request, read back the typed response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let v = self.call_raw(&req.to_value())?;
        Response::from_value(&v).map_err(Into::into)
    }

    /// Send one raw JSON line, read one JSON line back. Escape hatch for
    /// protocol tests; typed callers use [`Client::call`].
    pub fn call_raw(&mut self, msg: &Value) -> Result<Value> {
        let mut line = msg.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        crate::json::parse(resp.trim()).map_err(Into::into)
    }

    /// Classify a window; returns the typed outcome.
    pub fn classify(&mut self, window: &[f32], id: u64) -> Result<ClassifyOutcome> {
        let req = Request::Classify {
            id: Some(id),
            window: window.to_vec(),
            target: None,
            precision: None,
            deadline_ms: None,
        };
        match self.call(&req)? {
            Response::Result { outcome, .. } => Ok(outcome),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Set background device utilization; errors on rejection.
    pub fn set_load(&mut self, gpu: f64, cpu: f64) -> Result<()> {
        match self.call(&Request::SetLoad { id: None, gpu: Some(gpu), cpu: Some(cpu) })? {
            Response::LoadSet { .. } => Ok(()),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Fetch server metrics: (gpu_util, cpu_util, metrics object).
    pub fn stats(&mut self) -> Result<(f64, f64, Value)> {
        match self.call(&Request::Stats)? {
            Response::Stats { gpu_util, cpu_util, metrics } => Ok((gpu_util, cpu_util, metrics)),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Open a streaming session; returns its id. `None` precision means
    /// f32.
    pub fn open_session(&mut self, precision: Option<Precision>) -> Result<u64> {
        match self.call(&Request::OpenSession { id: None, precision })? {
            Response::SessionOpened { session, .. } => Ok(session),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Advance a session through flat `[steps, input_dim]` frames;
    /// returns per-step `(classes, logits)`.
    pub fn classify_stream(
        &mut self,
        session: u64,
        frames: &[f32],
        id: u64,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let req =
            Request::ClassifyStream { id: Some(id), session, frames: frames.to_vec() };
        match self.call(&req)? {
            Response::StreamResult { classes, logits, .. } => Ok((classes, logits)),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Close a session; returns the steps it consumed.
    pub fn close_session(&mut self, session: u64) -> Result<u64> {
        match self.call(&Request::CloseSession { id: None, session })? {
            Response::SessionClosed { steps, .. } => Ok(steps),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Ask the server to close this connection.
    pub fn quit(&mut self) -> Result<()> {
        match self.call(&Request::Quit)? {
            Response::Bye => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::coordinator::engine::testutil::FixedEngine;
    use crate::coordinator::OffloadPolicy;
    use crate::simulator::Target;

    /// Server over a fake-engine router — transport tests need no
    /// artifacts.
    fn server() -> Server {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        Server::bind("127.0.0.1:0", router).unwrap()
    }

    fn window() -> Vec<f32> {
        (0..30).map(|i| i as f32 / 30.0).collect()
    }

    #[test]
    fn tcp_round_trip() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();

        let outcome = client.classify(&window(), 1).unwrap();
        assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
        assert!(outcome.sim_latency_us > 0.0);
        assert_eq!(outcome.target, "cpu");
    }

    #[test]
    fn multiple_clients() {
        let srv = server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let w = window();
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    c.classify(&w, i).unwrap().class
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap() < 6);
        }
        assert_eq!(srv.connections_accepted(), 4);
    }

    #[test]
    fn typed_stats_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.set_load(0.4, 0.1).unwrap();
        let _ = client.classify(&window(), 0).unwrap();
        let (gpu_util, cpu_util, metrics) = client.stats().unwrap();
        assert!((gpu_util - 0.4).abs() < 1e-9);
        assert!((cpu_util - 0.1).abs() < 1e-9);
        assert_eq!(metrics.get("requests").as_usize(), Some(1));
        // The pipelined-dispatch stats surface on the wire.
        assert_eq!(metrics.get("shed").as_usize(), Some(0));
        assert_eq!(metrics.get("expired").as_usize(), Some(0));
        assert_eq!(metrics.get("queue_depth").as_usize(), Some(0));
        assert_eq!(metrics.get("inflight").get("gpu").as_usize(), Some(0));
        assert_eq!(metrics.get("inflight").get("cpu").as_usize(), Some(0));
    }

    #[test]
    fn invalid_load_is_rejected_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let err = client.set_load(7.0, 0.0).unwrap_err().to_string();
        assert!(err.contains("invalid_load"), "{err}");
    }

    #[test]
    fn quit_closes_connection() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.quit().unwrap();
        // Subsequent reads hit EOF -> call errors out.
        assert!(client.ping().is_err());
    }

    #[test]
    fn stop_is_idempotent() {
        let mut srv = server();
        srv.stop();
        srv.stop();
    }

    #[test]
    fn stop_joins_connection_threads_with_live_clients() {
        // Regression: stop used to join only the acceptor, leaking live
        // mobirnn-conn threads. Now it force-closes tracked sockets and
        // joins — it must return even though this client never hangs up.
        let mut srv = server();
        let _client = Client::connect(srv.addr()).unwrap();
        // Let the acceptor register the connection before stopping.
        while srv.connections_accepted() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        srv.stop();
    }

    #[test]
    fn connection_cap_refuses_with_typed_error() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let mut srv =
            Server::builder().max_connections(1).bind("127.0.0.1:0", router).unwrap();
        let _c1 = Client::connect(srv.addr()).unwrap();
        // The second connection is refused with one typed error line.
        let mut c2 = Client::connect(srv.addr()).unwrap();
        match c2.call(&Request::Ping).unwrap() {
            crate::server::Response::Error { code, message, .. } => {
                assert_eq!(code, crate::server::ErrorCode::Overloaded);
                assert!(message.contains("max_connections"), "{message}");
            }
            other => panic!("expected overloaded refusal, got {other:?}"),
        }
        while srv.connections_refused() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(srv.connections_accepted(), 1);
        drop(c2);
        srv.stop();
    }

    #[test]
    fn overload_sheds_with_typed_error_over_tcp() {
        use crate::coordinator::engine::testutil::SlowEngine;
        // A tiny admission queue in front of a slow engine: flooding 32
        // windows through one classify_batch must surface the typed
        // `overloaded` code end-to-end on the wire.
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .max_queue(2)
            .pool_depth(1)
            .engine(Box::new(SlowEngine::new(
                Target::CpuSingle,
                std::time::Duration::from_millis(200),
            )))
            .build()
            .unwrap();
        let srv = Server::bind("127.0.0.1:0", router).unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        let windows: Vec<Vec<f32>> = (0..32).map(|_| window()).collect();
        match client.call(&Request::ClassifyBatch { id: Some(1), windows }).unwrap() {
            crate::server::Response::Error { id, code, .. } => {
                assert_eq!(code, crate::server::ErrorCode::Overloaded, "typed code on the wire");
                assert_eq!(id, Some(1));
            }
            other => panic!("expected overloaded error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_request_type_keeps_connection_open() {
        // Regression: an unknown `type` on a v2 envelope must come back
        // as one typed bad_request line — never a dropped connection.
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let v = client
            .call_raw(&crate::json::parse(r#"{"type":"frobnicate","v":2,"id":1}"#).unwrap())
            .unwrap();
        assert_eq!(v.get("type").as_str(), Some("error"));
        assert_eq!(v.get("code").as_str(), Some("bad_request"));
        assert_eq!(v.get("id").as_usize(), Some(1));
        // The connection survived the bad line.
        client.ping().unwrap();
    }

    #[test]
    fn idle_timeout_closes_connection_cleanly() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let srv = Server::builder()
            .idle_timeout(std::time::Duration::from_millis(50))
            .bind("127.0.0.1:0", router)
            .unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();
        // Go quiet past the timeout: the server says bye and closes.
        std::thread::sleep(std::time::Duration::from_millis(250));
        let mut line = String::new();
        client.reader.read_line(&mut line).unwrap();
        let v = crate::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("type").as_str(), Some("bye"), "{line}");
        line.clear();
        assert_eq!(client.reader.read_line(&mut line).unwrap(), 0, "socket closed after bye");
    }

    #[test]
    fn zero_idle_timeout_means_never() {
        // Duration::ZERO disables the timeout (the CLI's `0` spelling).
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let srv = Server::builder()
            .idle_timeout(std::time::Duration::ZERO)
            .bind("127.0.0.1:0", router)
            .unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        client.ping().unwrap();
    }

    #[test]
    fn streaming_session_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let session = client.open_session(None).unwrap();
        let (classes, logits) =
            client.classify_stream(session, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6], 1).unwrap();
        assert_eq!(classes, vec![1, 1], "FixedEngine predicts class 1 per step");
        assert_eq!(logits.len(), 2 * 6);
        assert_eq!(client.close_session(session).unwrap(), 2);
        let err = client.classify_stream(session, &[0.1, 0.2, 0.3], 2).unwrap_err().to_string();
        assert!(err.contains("session_not_found"), "{err}");
    }
}
