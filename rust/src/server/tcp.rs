//! Threaded TCP transport for the JSON-lines protocol (v2) and, after
//! an in-band `hello {"proto":3}` upgrade, the binary frame protocol
//! (v3, [`super::frame`], DESIGN.md §12).
//!
//! The transport is deliberately thin: it reads lines, hands them to
//! [`protocol::handle_line`], writes back the typed [`Response`]'s wire
//! form, and closes when the response says so ([`Response::Bye`]). When
//! a `hello_ok {"proto":3}` goes out, the same connection switches to
//! length-prefixed frames in both directions and stays framed until it
//! closes. Write errors are never discarded: a failed reply write
//! counts `write_failed` and kills its connection.
//!
//! Connection discipline (DESIGN.md §9): every handler thread is
//! TRACKED — [`Server::stop`] force-closes the live sockets and joins
//! every `mobirnn-conn` thread, so stop is clean under load — and the
//! acceptor caps live connections at [`ServerBuilder::max_connections`],
//! refusing the overflow with a typed `overloaded` error line.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{Metrics, Precision, Router};
use crate::json::{FromValue, ToValue, Value};
use crate::server::frame;
use crate::server::protocol::{self, ClassifyOutcome, ErrorCode, Request, Response};

/// One tracked connection: the handle to join, plus a clone of the
/// stream so `stop` can force the handler's blocking read to return.
struct ConnSlot {
    stream: TcpStream,
    handle: std::thread::JoinHandle<()>,
}

/// Transport knobs; build with [`Server::builder`].
pub struct ServerBuilder {
    max_connections: usize,
    idle_timeout: Option<std::time::Duration>,
    max_proto: u64,
}

impl ServerBuilder {
    pub fn new() -> Self {
        Self { max_connections: 64, idle_timeout: None, max_proto: protocol::PROTO_V3_BINARY }
    }

    /// Cap on concurrently served connections (default 64). Clients
    /// beyond the cap receive one typed `overloaded` error line and are
    /// disconnected — bounded admission at the transport layer, the
    /// sibling of the scheduler's `max_queue`.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n.max(1);
        self
    }

    /// Close a connection that sends nothing for this long (default:
    /// never). Streaming clients hold connections open between chunks;
    /// without a bound, an abandoned stream pins one `mobirnn-conn`
    /// thread (and one `max_connections` slot) forever. Expiry is clean:
    /// the handler writes one `bye` line, then closes. Zero disables.
    pub fn idle_timeout(mut self, d: std::time::Duration) -> Self {
        self.idle_timeout = (!d.is_zero()).then_some(d);
        self
    }

    /// Highest wire protocol the server will negotiate (default 3).
    /// `2` keeps every connection on JSON lines: a `hello {"proto":3}`
    /// gets a typed `unsupported_version` refusal instead of an upgrade.
    pub fn max_proto(mut self, p: u64) -> Self {
        self.max_proto = p;
        self
    }

    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// `router` until stopped.
    pub fn bind(self, addr: &str, router: Router) -> Result<Server> {
        Server::start(addr, router, self.max_connections, self.idle_timeout, self.max_proto)
    }
}

impl Default for ServerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A running server; drop or call [`Server::stop`] to shut down.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicU64>,
    refused: Arc<AtomicU64>,
    conns: Arc<Mutex<Vec<ConnSlot>>>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start configuring a server (connection cap etc.).
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// [`ServerBuilder::bind`] with default knobs.
    pub fn bind(addr: &str, router: Router) -> Result<Self> {
        Self::builder().bind(addr, router)
    }

    fn start(
        addr: &str,
        router: Router,
        max_connections: usize,
        idle_timeout: Option<std::time::Duration>,
        max_proto: u64,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let refused = Arc::new(AtomicU64::new(0));
        let conns: Arc<Mutex<Vec<ConnSlot>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = Arc::clone(&stop);
        let accepted2 = Arc::clone(&connections);
        let refused2 = Arc::clone(&refused);
        let conns2 = Arc::clone(&conns);
        // Poll-accept so the stop flag is honored promptly.
        listener.set_nonblocking(true)?;
        let acceptor = std::thread::Builder::new()
            .name("mobirnn-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // Reap finished handlers so the cap counts
                            // live connections only.
                            let live = {
                                let mut conns = conns2.lock().unwrap();
                                conns.retain(|c| !c.handle.is_finished());
                                conns.len()
                            };
                            if live >= max_connections {
                                refused2.fetch_add(1, Ordering::Relaxed);
                                refuse_connection(stream, max_connections, &router.metrics);
                                continue;
                            }
                            // An untrackable connection would be
                            // invisible to the cap and un-joinable by
                            // stop(): refuse it rather than leak it.
                            let peer = match stream.try_clone() {
                                Ok(p) => p,
                                Err(_) => {
                                    refused2.fetch_add(1, Ordering::Relaxed);
                                    refuse_connection(stream, max_connections, &router.metrics);
                                    continue;
                                }
                            };
                            accepted2.fetch_add(1, Ordering::Relaxed);
                            let router = router.clone();
                            // conns_open is a gauge: up here, down when
                            // the handler (or a failed spawn) releases
                            // the connection.
                            let gauge = Arc::clone(&router.metrics);
                            gauge.conns_open.fetch_add(1, Ordering::Relaxed);
                            let conn_gauge = Arc::clone(&gauge);
                            let spawned = std::thread::Builder::new()
                                .name("mobirnn-conn".into())
                                .spawn(move || {
                                    let _ =
                                        handle_connection(stream, router, idle_timeout, max_proto);
                                    conn_gauge.conns_open.fetch_sub(1, Ordering::Relaxed);
                                });
                            match spawned {
                                Ok(handle) => {
                                    conns2
                                        .lock()
                                        .unwrap()
                                        .push(ConnSlot { stream: peer, handle });
                                }
                                Err(_) => {
                                    // The handler never ran; release the
                                    // gauge ourselves.
                                    gauge.conns_open.fetch_sub(1, Ordering::Relaxed);
                                }
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning acceptor")?;
        Ok(Self { addr: local, stop, connections, refused, conns, acceptor: Some(acceptor) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn connections_accepted(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    /// Connections turned away at the `max_connections` cap.
    pub fn connections_refused(&self) -> u64 {
        self.refused.load(Ordering::Relaxed)
    }

    /// Stop accepting, force-close every live connection, and join all
    /// handler threads. Previously only the acceptor was joined, leaking
    /// live `mobirnn-conn` threads past stop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let slots: Vec<ConnSlot> = std::mem::take(&mut *self.conns.lock().unwrap());
        for slot in slots {
            // Shutdown unblocks the handler's read (EOF/error); a
            // NotConnected error just means it already exited.
            let _ = slot.stream.shutdown(std::net::Shutdown::Both);
            let _ = slot.handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Tell an over-cap client why it is being dropped: one typed error
/// line, a write-side FIN, a brief drain of whatever the client already
/// sent, then close. The drain matters: dropping a socket with unread
/// bytes in the receive buffer sends RST, which can destroy the error
/// line before the client reads it. Shared with the event-driven server
/// ([`super::event`]), which applies the same cap discipline.
pub(crate) fn refuse_connection(mut stream: TcpStream, max_connections: usize, metrics: &Metrics) {
    let resp = Response::Error {
        id: None,
        code: ErrorCode::Overloaded,
        message: format!("server at max_connections={max_connections}"),
    };
    let mut line = resp.to_value().to_json();
    line.push('\n');
    if stream.write_all(line.as_bytes()).is_err() {
        // The client vanished before reading the refusal; count the
        // dead write and skip the drain -- nobody is listening.
        metrics.write_failed.fetch_add(1, Ordering::Relaxed);
        return;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(50)));
    let mut sink = [0u8; 512];
    for _ in 0..8 {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    router: Router,
    idle_timeout: Option<std::time::Duration>,
    max_proto: u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    if let Some(d) = idle_timeout {
        stream.set_read_timeout(Some(d)).ok();
    }
    let metrics = Arc::clone(&router.metrics);
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client hung up.
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle past the timeout (a stalled mid-line write counts
                // too): one `bye` line, then a clean close, so the
                // thread and its max_connections slot come back.
                let mut out = Response::Bye.to_value().to_json();
                out.push('\n');
                // A failed farewell still counts (via `send`); the
                // connection is closing either way.
                let _ = send(&mut writer, out.as_bytes(), &metrics);
                break;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = match protocol::decode_line(line.trim_end()) {
            // A hello above the server's cap (`--proto`) is refused
            // before it reaches the router; the connection stays JSON.
            Ok(Request::Hello { proto }) if proto > max_proto => {
                protocol::proto_capped_error(max_proto)
            }
            Ok(req) => protocol::handle_request(&router, req),
            Err(resp) => resp,
        };
        let close = matches!(resp, Response::Bye);
        let upgrade = matches!(resp, Response::HelloOk { proto: protocol::PROTO_V3_BINARY });
        let mut out = resp.to_value().to_json();
        out.push('\n');
        send(&mut writer, out.as_bytes(), &metrics)?;
        if upgrade {
            // The hello_ok above was the connection's last JSON line;
            // everything after it is length-prefixed frames.
            return serve_binary(&mut reader, &mut writer, &router, &metrics);
        }
        if close {
            break;
        }
    }
    Ok(())
}

/// Write a whole reply, counting failures: a failed write means the
/// client is gone, so the caller must treat the connection as dead.
/// (These errors used to be silently discarded.)
fn send(writer: &mut TcpStream, bytes: &[u8], metrics: &Metrics) -> Result<()> {
    writer.write_all(bytes).map_err(|e| {
        metrics.write_failed.fetch_add(1, Ordering::Relaxed);
        anyhow!("reply write failed: {e}")
    })
}

/// How a blocking read-to-fill ended.
enum ReadOutcome {
    /// The buffer was filled.
    Full,
    /// The peer closed -- at a frame boundary or mid-frame, either way
    /// the connection is over.
    Eof,
    /// The read timeout elapsed (the transport's idle timeout).
    Idle,
}

fn read_full(reader: &mut impl Read, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut filled = 0;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Ok(ReadOutcome::Eof),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(ReadOutcome::Idle)
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadOutcome::Full)
}

/// Serve binary frames on an upgraded connection (DESIGN.md §12). Each
/// request frame is answered before the next one is parsed -- the same
/// strict per-connection FIFO as the JSON loop. Header-level corruption
/// (bad magic, bad version, oversized length) loses the framing and
/// closes the connection; a malformed payload under a valid header gets
/// a typed error frame and the connection lives on.
fn serve_binary(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    router: &Router,
    metrics: &Metrics,
) -> Result<()> {
    loop {
        let mut header = [0u8; frame::HEADER_LEN];
        match read_full(reader, &mut header)? {
            ReadOutcome::Full => {}
            ReadOutcome::Eof => return Ok(()),
            ReadOutcome::Idle => {
                let _ = send(writer, &frame::encode_response(&Response::Bye), metrics);
                return Ok(());
            }
        }
        let h = frame::parse_header(&header).map_err(|e| anyhow!("bad frame header: {e}"))?;
        // Bounded by MAX_PAYLOAD -- parse_header already rejected
        // anything larger.
        let mut payload = vec![0u8; h.payload_len as usize];
        match read_full(reader, &mut payload)? {
            ReadOutcome::Full => {}
            ReadOutcome::Eof => return Ok(()),
            ReadOutcome::Idle => {
                let _ = send(writer, &frame::encode_response(&Response::Bye), metrics);
                return Ok(());
            }
        }
        metrics.frames_rx.fetch_add(1, Ordering::Relaxed);
        let resp = match frame::decode_request_body(&h, &payload) {
            Ok(req) => protocol::handle_request(router, req),
            Err(e) => Response::Error {
                id: h.id(),
                code: ErrorCode::BadRequest,
                message: format!("bad frame payload: {e}"),
            },
        };
        let close = matches!(resp, Response::Bye);
        send(writer, &frame::encode_response(&resp), metrics)?;
        metrics.frames_tx.fetch_add(1, Ordering::Relaxed);
        if close {
            return Ok(());
        }
    }
}

/// Minimal blocking client for tests, examples and the CLI. Speaks the
/// typed protocol: requests go out as [`Request`], replies come back as
/// [`Response`].
pub struct Client {
    pub(crate) reader: BufReader<TcpStream>,
    pub(crate) writer: TcpStream,
    /// After [`Client::negotiate_binary`]: speak frames, not JSON lines.
    binary: bool,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer, binary: false })
    }

    /// Upgrade this connection to the binary frame transport (proto 3).
    /// The hello goes out as the connection's last JSON line; every
    /// call after success uses length-prefixed frames.
    pub fn negotiate_binary(&mut self) -> Result<()> {
        match self.call(&Request::Hello { proto: protocol::PROTO_V3_BINARY })? {
            Response::HelloOk { proto } if proto == protocol::PROTO_V3_BINARY => {
                self.binary = true;
                Ok(())
            }
            Response::Error { code, message, .. } => {
                Err(anyhow!("server refused proto 3 ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Send one typed request, read back the typed response -- over
    /// whichever transport this connection negotiated.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        if self.binary {
            self.writer.write_all(&frame::encode_request(req))?;
            return self.read_frame();
        }
        let v = self.call_raw(&req.to_value())?;
        Response::from_value(&v).map_err(Into::into)
    }

    /// Read one complete response frame off the wire.
    fn read_frame(&mut self) -> Result<Response> {
        let mut header = [0u8; frame::HEADER_LEN];
        self.reader.read_exact(&mut header)?;
        let h = frame::parse_header(&header).map_err(|e| anyhow!("bad frame header: {e}"))?;
        let mut payload = vec![0u8; h.payload_len as usize];
        self.reader.read_exact(&mut payload)?;
        frame::decode_response_body(&h, &payload).map_err(|e| anyhow!("bad frame: {e}"))
    }

    /// Send one raw JSON line, read one JSON line back. Escape hatch for
    /// protocol tests; typed callers use [`Client::call`].
    pub fn call_raw(&mut self, msg: &Value) -> Result<Value> {
        let mut line = msg.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        crate::json::parse(resp.trim()).map_err(Into::into)
    }

    /// Classify a window; returns the typed outcome.
    pub fn classify(&mut self, window: &[f32], id: u64) -> Result<ClassifyOutcome> {
        let req = Request::Classify {
            id: Some(id),
            window: window.to_vec(),
            target: None,
            precision: None,
            deadline_ms: None,
            allow_degraded: false,
        };
        match self.call(&req)? {
            Response::Result { outcome, .. } => Ok(outcome),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Set background device utilization; errors on rejection.
    pub fn set_load(&mut self, gpu: f64, cpu: f64) -> Result<()> {
        match self.call(&Request::SetLoad { id: None, gpu: Some(gpu), cpu: Some(cpu) })? {
            Response::LoadSet { .. } => Ok(()),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Fetch server metrics: (gpu_util, cpu_util, metrics object).
    pub fn stats(&mut self) -> Result<(f64, f64, Value)> {
        match self.call(&Request::Stats)? {
            Response::Stats { gpu_util, cpu_util, metrics } => Ok((gpu_util, cpu_util, metrics)),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Open a streaming session; returns its id. `None` precision means
    /// f32.
    pub fn open_session(&mut self, precision: Option<Precision>) -> Result<u64> {
        match self.call(&Request::OpenSession { id: None, precision })? {
            Response::SessionOpened { session, .. } => Ok(session),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Advance a session through flat `[steps, input_dim]` frames;
    /// returns per-step `(classes, logits)`.
    pub fn classify_stream(
        &mut self,
        session: u64,
        frames: &[f32],
        id: u64,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let req = Request::ClassifyStream { id: Some(id), session, frames: frames.to_vec() };
        match self.call(&req)? {
            Response::StreamResult { classes, logits, .. } => Ok((classes, logits)),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Close a session; returns the steps it consumed.
    pub fn close_session(&mut self, session: u64) -> Result<u64> {
        match self.call(&Request::CloseSession { id: None, session })? {
            Response::SessionClosed { steps, .. } => Ok(steps),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Ask the server to close this connection.
    pub fn quit(&mut self) -> Result<()> {
        match self.call(&Request::Quit)? {
            Response::Bye => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::coordinator::engine::testutil::FixedEngine;
    use crate::coordinator::OffloadPolicy;
    use crate::simulator::Target;

    /// Server over a fake-engine router — transport tests need no
    /// artifacts.
    fn server() -> Server {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        Server::bind("127.0.0.1:0", router).unwrap()
    }

    fn window() -> Vec<f32> {
        (0..30).map(|i| i as f32 / 30.0).collect()
    }

    #[test]
    fn tcp_round_trip() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();

        let outcome = client.classify(&window(), 1).unwrap();
        assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
        assert!(outcome.sim_latency_us > 0.0);
        assert_eq!(outcome.target, "cpu");
    }

    #[test]
    fn multiple_clients() {
        let srv = server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let w = window();
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    c.classify(&w, i).unwrap().class
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap() < 6);
        }
        assert_eq!(srv.connections_accepted(), 4);
    }

    #[test]
    fn typed_stats_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.set_load(0.4, 0.1).unwrap();
        let _ = client.classify(&window(), 0).unwrap();
        let (gpu_util, cpu_util, metrics) = client.stats().unwrap();
        assert!((gpu_util - 0.4).abs() < 1e-9);
        assert!((cpu_util - 0.1).abs() < 1e-9);
        assert_eq!(metrics.get("requests").as_usize(), Some(1));
        // The pipelined-dispatch stats surface on the wire.
        assert_eq!(metrics.get("shed").as_usize(), Some(0));
        assert_eq!(metrics.get("expired").as_usize(), Some(0));
        assert_eq!(metrics.get("queue_depth").as_usize(), Some(0));
        assert_eq!(metrics.get("inflight").get("gpu").as_usize(), Some(0));
        assert_eq!(metrics.get("inflight").get("cpu").as_usize(), Some(0));
    }

    #[test]
    fn invalid_load_is_rejected_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let err = client.set_load(7.0, 0.0).unwrap_err().to_string();
        assert!(err.contains("invalid_load"), "{err}");
    }

    #[test]
    fn quit_closes_connection() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.quit().unwrap();
        // Subsequent reads hit EOF -> call errors out.
        assert!(client.ping().is_err());
    }

    #[test]
    fn stop_is_idempotent() {
        let mut srv = server();
        srv.stop();
        srv.stop();
    }

    #[test]
    fn stop_joins_connection_threads_with_live_clients() {
        // Regression: stop used to join only the acceptor, leaking live
        // mobirnn-conn threads. Now it force-closes tracked sockets and
        // joins — it must return even though this client never hangs up.
        let mut srv = server();
        let _client = Client::connect(srv.addr()).unwrap();
        // Let the acceptor register the connection before stopping.
        while srv.connections_accepted() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        srv.stop();
    }

    #[test]
    fn connection_cap_refuses_with_typed_error() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let mut srv = Server::builder().max_connections(1).bind("127.0.0.1:0", router).unwrap();
        let _c1 = Client::connect(srv.addr()).unwrap();
        // The second connection is refused with one typed error line.
        let mut c2 = Client::connect(srv.addr()).unwrap();
        match c2.call(&Request::Ping).unwrap() {
            crate::server::Response::Error { code, message, .. } => {
                assert_eq!(code, crate::server::ErrorCode::Overloaded);
                assert!(message.contains("max_connections"), "{message}");
            }
            other => panic!("expected overloaded refusal, got {other:?}"),
        }
        while srv.connections_refused() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(srv.connections_accepted(), 1);
        drop(c2);
        srv.stop();
    }

    #[test]
    fn overload_sheds_with_typed_error_over_tcp() {
        use crate::coordinator::engine::testutil::SlowEngine;
        // A tiny admission queue in front of a slow engine: flooding 32
        // windows through one classify_batch must surface the typed
        // `overloaded` code end-to-end on the wire.
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .max_queue(2)
            .pool_depth(1)
            .engine(Box::new(SlowEngine::new(
                Target::CpuSingle,
                std::time::Duration::from_millis(200),
            )))
            .build()
            .unwrap();
        let srv = Server::bind("127.0.0.1:0", router).unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        let windows: Vec<Vec<f32>> = (0..32).map(|_| window()).collect();
        match client.call(&Request::ClassifyBatch { id: Some(1), windows }).unwrap() {
            crate::server::Response::Error { id, code, .. } => {
                assert_eq!(code, crate::server::ErrorCode::Overloaded, "typed code on the wire");
                assert_eq!(id, Some(1));
            }
            other => panic!("expected overloaded error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_request_type_keeps_connection_open() {
        // Regression: an unknown `type` on a v2 envelope must come back
        // as one typed bad_request line — never a dropped connection.
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let v = client
            .call_raw(&crate::json::parse(r#"{"type":"frobnicate","v":2,"id":1}"#).unwrap())
            .unwrap();
        assert_eq!(v.get("type").as_str(), Some("error"));
        assert_eq!(v.get("code").as_str(), Some("bad_request"));
        assert_eq!(v.get("id").as_usize(), Some(1));
        // The connection survived the bad line.
        client.ping().unwrap();
    }

    #[test]
    fn idle_timeout_closes_connection_cleanly() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let srv = Server::builder()
            .idle_timeout(std::time::Duration::from_millis(50))
            .bind("127.0.0.1:0", router)
            .unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();
        // Go quiet past the timeout: the server says bye and closes.
        std::thread::sleep(std::time::Duration::from_millis(250));
        let mut line = String::new();
        client.reader.read_line(&mut line).unwrap();
        let v = crate::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("type").as_str(), Some("bye"), "{line}");
        line.clear();
        assert_eq!(client.reader.read_line(&mut line).unwrap(), 0, "socket closed after bye");
    }

    #[test]
    fn zero_idle_timeout_means_never() {
        // Duration::ZERO disables the timeout (the CLI's `0` spelling).
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let srv = Server::builder()
            .idle_timeout(std::time::Duration::ZERO)
            .bind("127.0.0.1:0", router)
            .unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        client.ping().unwrap();
    }

    #[test]
    fn streaming_session_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let session = client.open_session(None).unwrap();
        let (classes, logits) =
            client.classify_stream(session, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6], 1).unwrap();
        assert_eq!(classes, vec![1, 1], "FixedEngine predicts class 1 per step");
        assert_eq!(logits.len(), 2 * 6);
        assert_eq!(client.close_session(session).unwrap(), 2);
        let err = client.classify_stream(session, &[0.1, 0.2, 0.3], 2).unwrap_err().to_string();
        assert!(err.contains("session_not_found"), "{err}");
    }

    #[test]
    fn proto_cap_refuses_binary_upgrade() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        let srv = Server::builder().max_proto(2).bind("127.0.0.1:0", router).unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        let err = client.negotiate_binary().unwrap_err().to_string();
        assert!(err.contains("unsupported_version"), "{err}");
        // The refusal is an answer, not a hang-up: JSON still works.
        client.ping().unwrap();
    }

    #[test]
    fn binary_negotiation_and_full_round_trip() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.negotiate_binary().unwrap();
        client.ping().unwrap();
        // The whole op catalogue over frames.
        let outcome = client.classify(&window(), 7).unwrap();
        assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
        assert_eq!(outcome.target, "cpu");
        client.set_load(0.3, 0.1).unwrap();
        let session = client.open_session(None).unwrap();
        let (classes, logits) = client.classify_stream(session, &[0.1, 0.2, 0.3], 2).unwrap();
        assert_eq!(classes, vec![1]);
        assert_eq!(logits.len(), 6);
        assert_eq!(client.close_session(session).unwrap(), 1);
        let (gpu_util, _, metrics) = client.stats().unwrap();
        assert!((gpu_util - 0.3).abs() < 1e-9);
        assert_eq!(metrics.get("proto_v3_negotiated").as_usize(), Some(1));
        assert!(metrics.get("frames_rx").as_usize().unwrap() >= 6, "{metrics:?}");
        assert!(metrics.get("frames_tx").as_usize().unwrap() >= 5, "{metrics:?}");
        assert_eq!(metrics.get("conns_open").as_usize(), Some(1));
        client.quit().unwrap();
    }

    #[test]
    fn binary_malformed_payload_keeps_connection_open() {
        // A classify frame whose payload claims 99 floats but carries
        // none: valid header, malformed payload -> one typed error
        // frame, and the connection survives.
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.negotiate_binary().unwrap();
        let payload = 99u32.to_le_bytes();
        let mut bad = vec![0xA7u8, 3, 0x05, 0];
        bad.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bad.extend_from_slice(&0u64.to_le_bytes());
        bad.extend_from_slice(&payload);
        client.writer.write_all(&bad).unwrap();
        match client.read_frame().unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
            other => panic!("expected typed error frame, got {other:?}"),
        }
        client.ping().unwrap();
    }

    #[test]
    fn binary_garbage_header_closes_connection() {
        // Once framing is lost there is no way to resynchronize: the
        // server closes without an answer, and without a panic.
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.negotiate_binary().unwrap();
        client.writer.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        assert!(client.read_frame().is_err(), "no reply to garbage, just EOF");
        // The server is unharmed: new clients get full service.
        let mut c2 = Client::connect(srv.addr()).unwrap();
        c2.ping().unwrap();
    }

    #[test]
    fn binary_mid_frame_disconnect_is_a_clean_close() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.negotiate_binary().unwrap();
        // Half a header, then hang up.
        client.writer.write_all(&[0xA7, 3, 0x05]).unwrap();
        drop(client);
        let mut c2 = Client::connect(srv.addr()).unwrap();
        c2.ping().unwrap();
    }

    #[test]
    fn binary_oversized_length_closes_connection() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.negotiate_binary().unwrap();
        // Header declaring a payload over the hard bound: the server
        // must refuse to buffer it and drop the connection instead.
        let mut bad = vec![0xA7u8, 3, 0x05, 0];
        bad.extend_from_slice(&u32::MAX.to_le_bytes());
        bad.extend_from_slice(&0u64.to_le_bytes());
        client.writer.write_all(&bad).unwrap();
        assert!(client.read_frame().is_err());
    }
}
