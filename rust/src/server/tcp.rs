//! Threaded TCP transport for the JSON-lines protocol.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Router;
use crate::server::protocol::handle_message;

/// A running server; drop or call [`Server::stop`] to shut down.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicU64>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// `router` until stopped.
    pub fn bind(addr: &str, router: Router) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&connections);
        // Poll-accept so the stop flag is honored promptly.
        listener.set_nonblocking(true)?;
        let acceptor = std::thread::Builder::new()
            .name("mobirnn-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            conns2.fetch_add(1, Ordering::Relaxed);
                            let router = router.clone();
                            let _ = std::thread::Builder::new()
                                .name("mobirnn-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(stream, router);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning acceptor")?;
        Ok(Self { addr: local, stop, connections, acceptor: Some(acceptor) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn connections_accepted(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_connection(stream: TcpStream, router: Router) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_message(&router, &line);
        let mut out = resp.value.to_json();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
        if resp.close {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON line, read one JSON line back.
    pub fn call(&mut self, msg: &crate::json::Value) -> Result<crate::json::Value> {
        let mut line = msg.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        crate::json::parse(resp.trim()).map_err(Into::into)
    }

    /// Classify a window; returns (class, sim_latency_us, target).
    pub fn classify(&mut self, window: &[f32], id: usize) -> Result<(usize, f64, String)> {
        use crate::json::{obj, Value};
        let msg = obj([
            ("type", Value::from("classify")),
            ("id", Value::from(id)),
            ("window", Value::Arr(window.iter().map(|&v| Value::Num(v as f64)).collect())),
        ]);
        let resp = self.call(&msg)?;
        if resp.get("type").as_str() != Some("result") {
            return Err(anyhow::anyhow!("server error: {}", resp.to_json()));
        }
        Ok((
            resp.get("class").as_usize().context("class")?,
            resp.get("sim_latency_us").as_f64().context("sim_latency_us")?,
            resp.get("target").as_str().unwrap_or("?").to_string(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Manifest;
    use crate::coordinator::{DeviceState, OffloadPolicy, RouterConfig};
    use crate::json::{obj, Value};
    use crate::runtime::Runtime;
    use crate::simulator::DeviceProfile;
    use std::time::Duration;

    fn server() -> Option<Server> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let man = Manifest::load(dir).unwrap();
        let rt = Runtime::start(&man).unwrap();
        let router = Router::start(
            &man,
            rt,
            DeviceState::new(DeviceProfile::nexus5()),
            RouterConfig {
                policy: OffloadPolicy::CostModel,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        Some(Server::bind("127.0.0.1:0", router).unwrap())
    }

    #[test]
    fn tcp_round_trip() {
        let Some(srv) = server() else { return };
        let mut client = Client::connect(srv.addr()).unwrap();
        let pong = client.call(&obj([("type", Value::from("ping"))])).unwrap();
        assert_eq!(pong.get("type").as_str(), Some("pong"));

        let ds = crate::har::generate(2, 31);
        let (class, sim_us, target) = client.classify(ds.window(0), 1).unwrap();
        assert!(class < 6);
        assert!(sim_us > 0.0);
        assert_eq!(target, "gpu");
    }

    #[test]
    fn multiple_clients() {
        let Some(srv) = server() else { return };
        let ds = crate::har::generate(4, 37);
        let addr = srv.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let w = ds.window(i).to_vec();
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    c.classify(&w, i).unwrap().0
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap() < 6);
        }
        assert_eq!(srv.connections_accepted(), 4);
    }

    #[test]
    fn quit_closes_connection() {
        let Some(srv) = server() else { return };
        let mut client = Client::connect(srv.addr()).unwrap();
        let bye = client.call(&obj([("type", Value::from("quit"))])).unwrap();
        assert_eq!(bye.get("type").as_str(), Some("bye"));
        // Subsequent reads hit EOF -> call errors out.
        assert!(client.call(&obj([("type", Value::from("ping"))])).is_err());
    }

    #[test]
    fn stop_is_idempotent() {
        let Some(mut srv) = server() else { return };
        srv.stop();
        srv.stop();
    }
}
