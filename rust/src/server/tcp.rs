//! Threaded TCP transport for the JSON-lines protocol (v2).
//!
//! The transport is deliberately thin: it reads lines, hands them to
//! [`protocol::handle_line`], writes back the typed [`Response`]'s wire
//! form, and closes when the response says so ([`Response::Bye`]).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::Router;
use crate::json::{FromValue, ToValue, Value};
use crate::server::protocol::{self, ClassifyOutcome, Request, Response};

/// A running server; drop or call [`Server::stop`] to shut down.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicU64>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and serve
    /// `router` until stopped.
    pub fn bind(addr: &str, router: Router) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&connections);
        // Poll-accept so the stop flag is honored promptly.
        listener.set_nonblocking(true)?;
        let acceptor = std::thread::Builder::new()
            .name("mobirnn-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            conns2.fetch_add(1, Ordering::Relaxed);
                            let router = router.clone();
                            let _ = std::thread::Builder::new()
                                .name("mobirnn-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(stream, router);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning acceptor")?;
        Ok(Self { addr: local, stop, connections, acceptor: Some(acceptor) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn connections_accepted(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_connection(stream: TcpStream, router: Router) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone().context("clone stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = protocol::handle_line(&router, &line);
        let close = matches!(resp, Response::Bye);
        let mut out = resp.to_value().to_json();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
        if close {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, examples and the CLI. Speaks the
/// typed protocol: requests go out as [`Request`], replies come back as
/// [`Response`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Send one typed request, read back the typed response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let v = self.call_raw(&req.to_value())?;
        Response::from_value(&v).map_err(Into::into)
    }

    /// Send one raw JSON line, read one JSON line back. Escape hatch for
    /// protocol tests; typed callers use [`Client::call`].
    pub fn call_raw(&mut self, msg: &Value) -> Result<Value> {
        let mut line = msg.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        crate::json::parse(resp.trim()).map_err(Into::into)
    }

    /// Classify a window; returns the typed outcome.
    pub fn classify(&mut self, window: &[f32], id: u64) -> Result<ClassifyOutcome> {
        let req = Request::Classify {
            id: Some(id),
            window: window.to_vec(),
            target: None,
            deadline_ms: None,
        };
        match self.call(&req)? {
            Response::Result { outcome, .. } => Ok(outcome),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Set background device utilization; errors on rejection.
    pub fn set_load(&mut self, gpu: f64, cpu: f64) -> Result<()> {
        match self.call(&Request::SetLoad { id: None, gpu: Some(gpu), cpu: Some(cpu) })? {
            Response::LoadSet { .. } => Ok(()),
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error ({}): {message}", code.as_str()))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Fetch server metrics: (gpu_util, cpu_util, metrics object).
    pub fn stats(&mut self) -> Result<(f64, f64, Value)> {
        match self.call(&Request::Stats)? {
            Response::Stats { gpu_util, cpu_util, metrics } => Ok((gpu_util, cpu_util, metrics)),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Ask the server to close this connection.
    pub fn quit(&mut self) -> Result<()> {
        match self.call(&Request::Quit)? {
            Response::Bye => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::coordinator::engine::testutil::FixedEngine;
    use crate::coordinator::OffloadPolicy;
    use crate::simulator::Target;

    /// Server over a fake-engine router — transport tests need no
    /// artifacts.
    fn server() -> Server {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap();
        Server::bind("127.0.0.1:0", router).unwrap()
    }

    fn window() -> Vec<f32> {
        (0..30).map(|i| i as f32 / 30.0).collect()
    }

    #[test]
    fn tcp_round_trip() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();

        let outcome = client.classify(&window(), 1).unwrap();
        assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
        assert!(outcome.sim_latency_us > 0.0);
        assert_eq!(outcome.target, "cpu");
    }

    #[test]
    fn multiple_clients() {
        let srv = server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let w = window();
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    c.classify(&w, i).unwrap().class
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap() < 6);
        }
        assert_eq!(srv.connections_accepted(), 4);
    }

    #[test]
    fn typed_stats_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.set_load(0.4, 0.1).unwrap();
        let _ = client.classify(&window(), 0).unwrap();
        let (gpu_util, cpu_util, metrics) = client.stats().unwrap();
        assert!((gpu_util - 0.4).abs() < 1e-9);
        assert!((cpu_util - 0.1).abs() < 1e-9);
        assert_eq!(metrics.get("requests").as_usize(), Some(1));
    }

    #[test]
    fn invalid_load_is_rejected_over_tcp() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let err = client.set_load(7.0, 0.0).unwrap_err().to_string();
        assert!(err.contains("invalid_load"), "{err}");
    }

    #[test]
    fn quit_closes_connection() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.quit().unwrap();
        // Subsequent reads hit EOF -> call errors out.
        assert!(client.ping().is_err());
    }

    #[test]
    fn stop_is_idempotent() {
        let mut srv = server();
        srv.stop();
        srv.stop();
    }
}
