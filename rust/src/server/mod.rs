//! TCP serving front-ends: JSON lines (protocol v2) and binary frames
//! (protocol v3, negotiated per connection).
//!
//! The image's vendor set has no tokio, so this is a classic std::net
//! threaded server: one acceptor, one handler thread per connection,
//! all feeding the shared [`crate::coordinator::Router`]. The protocol
//! is newline-delimited JSON, one typed message per line; every message
//! is a [`protocol::Request`]/[`protocol::Response`] variant converted
//! through the [`crate::json::ToValue`]/[`crate::json::FromValue`]
//! codecs (full catalogue: DESIGN.md §7):
//!
//! ```text
//! → {"type":"classify","id":7,"window":[... 1152 floats ...]}
//! ← {"type":"result","v":2,"id":7,"class":3,"label":"sitting",
//!    "sim_latency_us":36123.4,"wall_latency_us":812.0,
//!    "target":"gpu","batch_size":2}
//! → {"type":"classify_batch","id":8,"windows":[[...],[...]]}
//! ← {"type":"batch_result","v":2,"id":8,"results":[{...},{...}]}
//! → {"type":"set_load","gpu":0.8,"cpu":0.5}      ← Fig 7 knobs
//! ← {"type":"load_set","v":2,"gpu":0.8,"cpu":0.5}
//! → {"type":"set_load","gpu":7.0}
//! ← {"type":"error","v":2,"code":"invalid_load","message":"..."}
//! → {"type":"stats"}
//! ← {"type":"stats","v":2,"gpu_util":...,"cpu_util":...,"metrics":{...}}
//! → {"type":"ping"}   ← {"type":"pong","v":2}
//! → {"type":"quit"}   ← {"type":"bye","v":2}    (connection closes)
//! → {"type":"open_session","id":1,"precision":"int8"}
//! ← {"type":"session_opened","v":2,"id":1,"session":9,
//!    "target":"cpu-quant","ttl_ms":30000}
//! → {"type":"classify_stream","id":2,"session":9,"frames":[... k*D ...]}
//! ← {"type":"stream_result","v":2,"id":2,"session":9,"steps":k,
//!    "classes":[...],"logits":[... k*C ...],"wall_latency_us":...,
//!    "target":"cpu-quant"}
//! → {"type":"close_session","session":9}
//! ← {"type":"session_closed","v":2,"session":9,"steps":42}
//! ```
//!
//! Streaming sessions (DESIGN.md §11) keep per-client LSTM state
//! server-side between `classify_stream` calls; an idle session is
//! evicted after its TTL and later references answer with the typed
//! `session_not_found` / `session_expired` error codes.
//!
//! Wire protocol v3 (DESIGN.md §12) layers a binary transport on the
//! same catalogue: a client sends `{"type":"hello","proto":3}` as a
//! JSON line and, after the `hello_ok`, both directions switch to
//! length-prefixed frames ([`frame`]) — raw little-endian f32 tensors
//! instead of decimal text. JSON remains the default and the fallback.
//! Two server front-ends speak both transports: the thread-per-
//! connection [`Server`] ([`tcp`]) and the event-driven [`EventServer`]
//! ([`event`]), which multiplexes thousands of connections over a
//! fixed set of `poll(2)` I/O threads.

pub mod event;
pub mod frame;
pub mod protocol;
pub mod tcp;

pub use event::{EventServer, EventServerBuilder};
pub use frame::{F32View, FrameError};
pub use protocol::{
    handle_line, handle_request, ClassifyOutcome, ErrorCode, Request, Response,
    PROTOCOL_VERSION,
};
pub use tcp::{Client, Server, ServerBuilder};
