//! TCP JSON-lines serving front-end.
//!
//! The image's vendor set has no tokio, so this is a classic std::net
//! threaded server: one acceptor, one handler thread per connection,
//! all feeding the shared [`Router`]. The protocol is newline-delimited
//! JSON (one object per line):
//!
//! ```text
//! → {"type":"classify","id":7,"window":[... 1152 floats ...]}
//! ← {"type":"result","id":7,"class":3,"label":"sitting",
//!    "sim_latency_us":36123.4,"wall_latency_us":812.0,
//!    "target":"gpu","batch_size":2}
//! → {"type":"set_load","gpu":0.8,"cpu":0.5}      ← Fig 7 knobs
//! ← {"type":"ok"}
//! → {"type":"stats"}
//! ← {"type":"stats", ...Metrics::to_json()...}
//! → {"type":"ping"}   ← {"type":"pong"}
//! ```

pub mod protocol;
pub mod tcp;

pub use protocol::{handle_message, Response};
pub use tcp::{Client, Server};
