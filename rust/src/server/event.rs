//! Event-driven connection multiplexer (DESIGN.md §12).
//!
//! The threaded server ([`super::tcp`]) spends one `mobirnn-conn`
//! thread per connection — fine for dozens of clients, fatal for
//! thousands: the paper's point that overhead around the kernel
//! dominates once the kernel is fast applies to threads as much as to
//! serialization. This module serves the same two wire protocols
//! (JSON lines, and binary frames after a `hello {"proto":3}` upgrade)
//! from a FIXED set of I/O threads, each multiplexing its share of
//! connections over nonblocking sockets with `poll(2)` — reached
//! through a minimal FFI declaration rather than a dependency.
//!
//! Per-connection state machine:
//!
//! ```text
//! readable ──▶ rbuf ──▶ parse (line | frame) ──▶ dispatch async
//!                 ▲                                   │ completion
//!                 │ POLLIN off while a request         ▼ queue + waker
//!  backpressure ──┘ is in flight (strict FIFO)    wbuf ──▶ writable
//! ```
//!
//! Scheduling rules, each load-bearing:
//!
//! - **One request in flight per connection.** Parsing pauses (and
//!   POLLIN is dropped from the poll set) until the completion for the
//!   dispatched request lands, so replies keep request order and a
//!   flood from one client backs up in ITS socket buffer, not in
//!   server memory.
//! - **Replies arrive over a completion queue.** [`super::protocol`]'s
//!   `handle_request_async` fires its callback on whichever pool
//!   worker resolved the request; the callback just enqueues
//!   `(slot, generation, response)` and pokes the loop's waker pipe.
//!   Generations guard against slot reuse: a completion for a dead
//!   connection is dropped, never sent to the slot's new tenant.
//! - **Write backpressure.** Responses append to a per-connection
//!   write buffer flushed on POLLOUT; while more than
//!   [`WRITE_HIGH_WATER`] bytes are unflushed, parsing pauses too. A
//!   client that stops reading stops being served, at bounded memory.
//! - **Upgrades happen at completion time.** The `hello_ok {proto:3}`
//!   reply is encoded in JSON (the old mode), then the connection
//!   flips to frames — bytes a client pipelined right behind its hello
//!   are already sitting unparsed in `rbuf` and get decoded as frames.
//!
//! The admission story matches the threaded server: a global live-count
//! cap, refusals via the same typed `overloaded` line, and the
//! `conns_open` / `frames_rx` / `frames_tx` / `write_failed` counters
//! reported through [`crate::coordinator::Metrics`].

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{Metrics, Router};
use crate::json::ToValue;
use crate::server::frame;
use crate::server::protocol::{self, ErrorCode, Response};
use crate::server::tcp::refuse_connection;

/// Parsing pauses while a connection has this many reply bytes
/// unflushed; they drain before any new request is decoded.
const WRITE_HIGH_WATER: usize = 1 << 20;

/// Per-iteration read budget for one connection, so a firehose client
/// cannot monopolize its I/O loop.
const READ_CHUNK: usize = 1 << 20;

/// Poll timeout: bounds the latency of stop-flag and idle-timeout
/// checks when no socket activity wakes the loop sooner.
const POLL_TIMEOUT_MS: i32 = 50;

/// `poll(2)` via a minimal FFI declaration — the only system interface
/// this module needs beyond std's sockets.
mod sys {
    use std::os::raw::{c_int, c_short, c_ulong};

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
}

/// Transport knobs; build with [`EventServer::builder`].
pub struct EventServerBuilder {
    io_threads: usize,
    max_connections: usize,
    idle_timeout: Option<Duration>,
    stall_timeout: Option<Duration>,
    max_proto: u64,
}

impl EventServerBuilder {
    pub fn new() -> Self {
        Self {
            io_threads: 2,
            max_connections: 1024,
            idle_timeout: None,
            stall_timeout: Some(Duration::from_secs(5)),
            max_proto: protocol::PROTO_V3_BINARY,
        }
    }

    /// Number of I/O loop threads (default 2). Connections are dealt
    /// round-robin at accept time; each loop multiplexes its share.
    pub fn io_threads(mut self, n: usize) -> Self {
        self.io_threads = n.max(1);
        self
    }

    /// Cap on concurrently served connections (default 1024). Clients
    /// beyond the cap receive one typed `overloaded` error line and are
    /// disconnected.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n.max(1);
        self
    }

    /// Close a connection that sends nothing for this long (default:
    /// never). Expiry is clean — one `bye` in the connection's current
    /// transport, a flush, then close. Zero disables.
    pub fn idle_timeout(mut self, d: Duration) -> Self {
        self.idle_timeout = (!d.is_zero()).then_some(d);
        self
    }

    /// Highest wire protocol the server will negotiate (default 3).
    /// `2` keeps every connection on JSON lines: a `hello {"proto":3}`
    /// gets a typed `unsupported_version` refusal instead of an upgrade.
    pub fn max_proto(mut self, p: u64) -> Self {
        self.max_proto = p;
        self
    }

    /// Close a connection whose write backlog stays at or above the
    /// high-water mark for this long (default 5 s) — a peer that stops
    /// reading while replies pile up would otherwise park its reads
    /// forever. The forfeited backlog is replaced by one typed
    /// `overloaded` error line, `conns_stalled` is counted, and the
    /// socket closes. Zero disables.
    pub fn stall_timeout(mut self, d: Duration) -> Self {
        self.stall_timeout = (!d.is_zero()).then_some(d);
        self
    }

    /// Bind `addr` and serve `router` until stopped.
    pub fn bind(self, addr: &str, router: Router) -> Result<EventServer> {
        EventServer::start(
            addr,
            router,
            self.io_threads,
            self.max_connections,
            self.idle_timeout,
            self.stall_timeout,
            self.max_proto,
        )
    }
}

impl Default for EventServerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A running event-driven server; drop or call [`EventServer::stop`]
/// to shut down.
pub struct EventServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    refused: Arc<AtomicU64>,
    wakers: Vec<Arc<UnixStream>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl EventServer {
    pub fn builder() -> EventServerBuilder {
        EventServerBuilder::new()
    }

    /// [`EventServerBuilder::bind`] with default knobs.
    pub fn bind(addr: &str, router: Router) -> Result<Self> {
        Self::builder().bind(addr, router)
    }

    fn start(
        addr: &str,
        router: Router,
        io_threads: usize,
        max_connections: usize,
        idle_timeout: Option<Duration>,
        stall_timeout: Option<Duration>,
        max_proto: u64,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(0));
        let accepted = Arc::new(AtomicU64::new(0));
        let refused = Arc::new(AtomicU64::new(0));
        let metrics = Arc::clone(&router.metrics);

        let mut wakers = Vec::with_capacity(io_threads);
        let mut intakes = Vec::with_capacity(io_threads);
        let mut handles = Vec::with_capacity(io_threads);
        for i in 0..io_threads {
            let (waker_tx, waker_rx) = UnixStream::pair().context("waker pair")?;
            waker_tx.set_nonblocking(true)?;
            waker_rx.set_nonblocking(true)?;
            let waker = Arc::new(waker_tx);
            let intake: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
            let (done_tx, done_rx) = mpsc::channel();
            let ctx = DispatchCtx {
                router: router.clone(),
                metrics: Arc::clone(&metrics),
                done_tx,
                waker: Arc::clone(&waker),
                max_proto,
            };
            let stop2 = Arc::clone(&stop);
            let live2 = Arc::clone(&live);
            let intake2 = Arc::clone(&intake);
            let handle = std::thread::Builder::new()
                .name(format!("mobirnn-io-{i}"))
                .spawn(move || {
                    io_loop(
                        ctx,
                        stop2,
                        live2,
                        intake2,
                        waker_rx,
                        done_rx,
                        idle_timeout,
                        stall_timeout,
                    )
                })
                .context("spawning io loop")?;
            wakers.push(waker);
            intakes.push(intake);
            handles.push(handle);
        }

        let ports: Vec<_> = wakers.iter().cloned().zip(intakes.iter().cloned()).collect();
        let stop2 = Arc::clone(&stop);
        let live2 = Arc::clone(&live);
        let accepted2 = Arc::clone(&accepted);
        let refused2 = Arc::clone(&refused);
        let acceptor = std::thread::Builder::new()
            .name("mobirnn-accept".into())
            .spawn(move || {
                let mut next = 0usize;
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if live2.load(Ordering::Relaxed) >= max_connections {
                                refused2.fetch_add(1, Ordering::Relaxed);
                                refuse_connection(stream, max_connections, &metrics);
                                continue;
                            }
                            // The acceptor owns the gauge increment;
                            // whichever loop closes the connection
                            // decrements.
                            live2.fetch_add(1, Ordering::Relaxed);
                            metrics.conns_open.fetch_add(1, Ordering::Relaxed);
                            accepted2.fetch_add(1, Ordering::Relaxed);
                            let (waker, intake) = &ports[next % ports.len()];
                            next = next.wrapping_add(1);
                            intake.lock().unwrap().push(stream);
                            wake(waker);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning acceptor")?;

        Ok(Self { addr: local, stop, accepted, refused, wakers, handles, acceptor: Some(acceptor) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn connections_accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Connections turned away at the `max_connections` cap.
    pub fn connections_refused(&self) -> u64 {
        self.refused.load(Ordering::Relaxed)
    }

    /// Stop accepting, wake every I/O loop, and join all threads. Live
    /// connections are dropped (clients see EOF).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for w in &self.wakers {
            wake(w);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for EventServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---- the I/O loop ----------------------------------------------------

/// Everything a dispatched request needs to find its way back.
struct DispatchCtx {
    router: Router,
    metrics: Arc<Metrics>,
    done_tx: mpsc::Sender<Completion>,
    waker: Arc<UnixStream>,
    max_proto: u64,
}

/// A resolved request on its way back to the loop that dispatched it.
struct Completion {
    slot: usize,
    generation: u64,
    resp: Response,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Json,
    Binary,
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    /// Guards against slot reuse: completions carry the generation they
    /// were dispatched under and are dropped on mismatch.
    generation: u64,
    mode: Mode,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` already written; `wbuf` compacts when drained.
    wpos: usize,
    /// A request has been dispatched and its completion has not landed.
    inflight: bool,
    /// `bye` (or idle expiry) happened: flush, then close.
    closing: bool,
    last_active: Instant,
    /// When the write backlog first reached [`WRITE_HIGH_WATER`] and
    /// stayed there; cleared the moment it drains below. The stall
    /// deadline measures from here.
    stalled_since: Option<Instant>,
}

impl Conn {
    fn new(stream: TcpStream, generation: u64) -> Self {
        Self {
            stream,
            generation,
            mode: Mode::Json,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            inflight: false,
            closing: false,
            last_active: Instant::now(),
            stalled_since: None,
        }
    }

    /// Unflushed reply bytes.
    fn backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// Poke a loop's waker pipe so its `poll` returns now. A full pipe is
/// fine — it already guarantees a pending wakeup.
fn wake(waker: &UnixStream) {
    let mut w = waker;
    let _ = w.write_all(&[1u8]);
}

fn drain_waker(waker: &UnixStream) {
    let mut r = waker;
    let mut sink = [0u8; 64];
    while matches!(r.read(&mut sink), Ok(n) if n > 0) {}
}

#[allow(clippy::too_many_arguments)]
fn io_loop(
    ctx: DispatchCtx,
    stop: Arc<AtomicBool>,
    live: Arc<AtomicUsize>,
    intake: Arc<Mutex<Vec<TcpStream>>>,
    waker_rx: UnixStream,
    done_rx: mpsc::Receiver<Completion>,
    idle_timeout: Option<Duration>,
    stall_timeout: Option<Duration>,
) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut next_generation: u64 = 0;
    let mut fds: Vec<sys::PollFd> = Vec::new();
    let mut fd_slots: Vec<usize> = Vec::new();

    while !stop.load(Ordering::Relaxed) {
        // 1. Adopt newly accepted connections.
        for stream in intake.lock().unwrap().drain(..) {
            if stream.set_nonblocking(true).is_err() {
                // Cannot be multiplexed; undo the acceptor's gauge.
                live.fetch_sub(1, Ordering::Relaxed);
                ctx.metrics.conns_open.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            stream.set_nodelay(true).ok();
            next_generation += 1;
            let conn = Conn::new(stream, next_generation);
            match conns.iter().position(Option::is_none) {
                Some(slot) => conns[slot] = Some(conn),
                None => conns.push(Some(conn)),
            }
        }

        // 2. Apply completions from the pool workers.
        while let Ok(done) = done_rx.try_recv() {
            let alive = match conns.get_mut(done.slot).and_then(Option::as_mut) {
                Some(conn) if conn.generation == done.generation => {
                    on_completion(conn, done.resp, &ctx);
                    parse_more(conn, &ctx, done.slot) && flush(conn, &ctx.metrics)
                }
                // The connection died (or the slot was re-let) while
                // the request ran; drop the orphan reply.
                _ => continue,
            };
            if !alive {
                close(&mut conns, done.slot, &live, &ctx.metrics);
            }
        }

        // 3. Build the poll set: the waker, then every live socket.
        fds.clear();
        fd_slots.clear();
        fds.push(sys::PollFd { fd: waker_rx.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        fd_slots.push(usize::MAX);
        for (slot, entry) in conns.iter().enumerate() {
            if let Some(conn) = entry {
                let mut events = 0;
                if !conn.inflight && !conn.closing && conn.backlog() < WRITE_HIGH_WATER {
                    events |= sys::POLLIN;
                }
                if conn.backlog() > 0 {
                    events |= sys::POLLOUT;
                }
                fds.push(sys::PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
                fd_slots.push(slot);
            }
        }

        // 4. Wait for readiness (or the timeout, for stop/idle checks).
        let rc = unsafe {
            sys::poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, POLL_TIMEOUT_MS)
        };
        if rc < 0 {
            // EINTR or a transient failure: go around. The sleep bounds
            // the retry rate if the failure is persistent.
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }

        if fds[0].revents & sys::POLLIN != 0 {
            drain_waker(&waker_rx);
        }

        // 5. Service readiness per connection.
        for (pf, &slot) in fds.iter().zip(fd_slots.iter()).skip(1) {
            let revents = pf.revents;
            if revents == 0 {
                continue;
            }
            let alive = match conns[slot].as_mut() {
                Some(conn) => {
                    if revents & (sys::POLLERR | sys::POLLNVAL) != 0 {
                        false
                    } else {
                        let mut ok = true;
                        if revents & (sys::POLLIN | sys::POLLHUP) != 0 {
                            ok = service_readable(conn, &ctx, slot);
                        }
                        ok && flush(conn, &ctx.metrics)
                    }
                }
                None => continue,
            };
            if !alive {
                close(&mut conns, slot, &live, &ctx.metrics);
            }
        }

        // 6. Idle expiry, write-stall expiry, and drained-close sweep.
        let now = Instant::now();
        for slot in 0..conns.len() {
            let mut kill = false;
            if let Some(conn) = conns[slot].as_mut() {
                if let Some(d) = idle_timeout {
                    if !conn.closing
                        && !conn.inflight
                        && now.duration_since(conn.last_active) >= d
                    {
                        enqueue_response(conn, &Response::Bye, &ctx.metrics);
                        conn.closing = true;
                        if !flush(conn, &ctx.metrics) {
                            kill = true;
                        }
                    }
                }
                // Write-stall deadline (DESIGN.md §15): past the
                // high-water mark this connection's reads are parked;
                // a peer that never drains would hold them parked
                // forever. After the deadline the unread backlog is
                // forfeit — replaced by one typed `overloaded` line —
                // and the connection closes.
                if let Some(d) = stall_timeout {
                    if conn.backlog() >= WRITE_HIGH_WATER {
                        let since = *conn.stalled_since.get_or_insert(now);
                        if now.duration_since(since) >= d {
                            ctx.metrics.conns_stalled.fetch_add(1, Ordering::Relaxed);
                            conn.wbuf.clear();
                            conn.wpos = 0;
                            let resp = Response::Error {
                                id: None,
                                code: ErrorCode::Overloaded,
                                message: "write backlog stalled past deadline".into(),
                            };
                            enqueue_response(conn, &resp, &ctx.metrics);
                            conn.closing = true;
                            let _ = flush(conn, &ctx.metrics);
                            kill = true;
                        }
                    } else {
                        conn.stalled_since = None;
                    }
                }
                if conn.closing && !conn.inflight && conn.backlog() == 0 {
                    kill = true;
                }
            }
            if kill {
                close(&mut conns, slot, &live, &ctx.metrics);
            }
        }
    }

    // Shutdown: release the gauge for everything this loop still holds,
    // including connections the acceptor queued but we never adopted.
    let stranded = intake.lock().unwrap().drain(..).count();
    let open = conns.iter().filter(|c| c.is_some()).count() + stranded;
    if open > 0 {
        live.fetch_sub(open, Ordering::Relaxed);
        ctx.metrics.conns_open.fetch_sub(open as u64, Ordering::Relaxed);
    }
}

/// Drain readable bytes into `rbuf`, then parse. `false` means the
/// connection is dead (EOF, error, or lost framing) and must be closed.
fn service_readable(conn: &mut Conn, ctx: &DispatchCtx, slot: usize) -> bool {
    let mut buf = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => return false, // EOF — mid-frame or not, it is over.
            Ok(n) => {
                conn.rbuf.extend_from_slice(&buf[..n]);
                conn.last_active = Instant::now();
                if conn.rbuf.len() >= READ_CHUNK {
                    break; // Enough for this turn; POLLIN will re-fire.
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    parse_more(conn, ctx, slot)
}

/// Decode and act on as many buffered requests as the scheduling rules
/// allow (one in flight; write backlog under the high-water mark).
/// `false` means framing was lost and the connection must close.
fn parse_more(conn: &mut Conn, ctx: &DispatchCtx, slot: usize) -> bool {
    loop {
        if conn.inflight || conn.closing || conn.backlog() >= WRITE_HIGH_WATER {
            return true;
        }
        match conn.mode {
            Mode::Json => {
                let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') else {
                    return true;
                };
                let line: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                match std::str::from_utf8(&line) {
                    Ok(text) if text.trim().is_empty() => {}
                    Ok(text) => match protocol::decode_line(text.trim_end()) {
                        Ok(req) => admit(conn, ctx, slot, req),
                        Err(resp) => enqueue_response(conn, &resp, &ctx.metrics),
                    },
                    Err(_) => {
                        let resp = Response::Error {
                            id: None,
                            code: ErrorCode::BadJson,
                            message: "line is not utf-8".into(),
                        };
                        enqueue_response(conn, &resp, &ctx.metrics);
                    }
                }
            }
            Mode::Binary => {
                let total = match frame::frame_len(&conn.rbuf) {
                    Ok(Some(n)) => n,
                    Ok(None) => return true,
                    // Bad magic/version/length: framing is lost and
                    // there is no way to resynchronize.
                    Err(_) => return false,
                };
                if conn.rbuf.len() < total {
                    return true;
                }
                let frame_bytes: Vec<u8> = conn.rbuf.drain(..total).collect();
                ctx.metrics.frames_rx.fetch_add(1, Ordering::Relaxed);
                match frame::decode_request(&frame_bytes) {
                    Ok(req) => admit(conn, ctx, slot, req),
                    Err(e) => {
                        // Valid framing, malformed payload: answer with
                        // a typed error and keep the connection.
                        let id = frame::parse_header(&frame_bytes).ok().and_then(|h| h.id());
                        let resp = Response::Error {
                            id,
                            code: ErrorCode::BadRequest,
                            message: format!("bad frame payload: {e}"),
                        };
                        enqueue_response(conn, &resp, &ctx.metrics);
                    }
                }
            }
        }
    }
}

/// Gate one decoded request: a hello above the server's cap
/// (`--proto` on the CLI) is answered inline with a typed refusal;
/// everything else dispatches to the router.
fn admit(conn: &mut Conn, ctx: &DispatchCtx, slot: usize, req: protocol::Request) {
    match req {
        protocol::Request::Hello { proto } if proto > ctx.max_proto => {
            let resp = protocol::proto_capped_error(ctx.max_proto);
            enqueue_response(conn, &resp, &ctx.metrics);
        }
        req => dispatch(conn, ctx, slot, req),
    }
}

/// Hand one request to the router without blocking this thread. The
/// completion callback may fire inline (sync ops) or later from a pool
/// worker; either way it lands in the completion queue and is applied
/// by the loop, so the ordering rules hold in both cases.
fn dispatch(conn: &mut Conn, ctx: &DispatchCtx, slot: usize, req: protocol::Request) {
    conn.inflight = true;
    let tx = ctx.done_tx.clone();
    let waker = Arc::clone(&ctx.waker);
    let generation = conn.generation;
    protocol::handle_request_async(
        &ctx.router,
        req,
        Box::new(move |resp| {
            let _ = tx.send(Completion { slot, generation, resp });
            wake(&waker);
        }),
    );
}

/// Apply a resolved request to its connection: encode the reply in the
/// connection's CURRENT transport, then run transport reactions (`bye`
/// closes; `hello_ok {proto:3}` flips the mode for everything after).
fn on_completion(conn: &mut Conn, resp: Response, ctx: &DispatchCtx) {
    conn.inflight = false;
    conn.last_active = Instant::now();
    if matches!(resp, Response::Bye) {
        conn.closing = true;
    }
    let upgrade = matches!(resp, Response::HelloOk { proto: protocol::PROTO_V3_BINARY });
    enqueue_response(conn, &resp, &ctx.metrics);
    if upgrade {
        conn.mode = Mode::Binary;
    }
}

fn enqueue_response(conn: &mut Conn, resp: &Response, metrics: &Metrics) {
    match conn.mode {
        Mode::Json => {
            let mut line = resp.to_value().to_json();
            line.push('\n');
            conn.wbuf.extend_from_slice(line.as_bytes());
        }
        Mode::Binary => {
            conn.wbuf.extend_from_slice(&frame::encode_response(resp));
            metrics.frames_tx.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Write as much backlog as the socket accepts. `false` means the
/// write failed — the client is gone and the connection must close.
fn flush(conn: &mut Conn, metrics: &Metrics) -> bool {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                metrics.write_failed.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                metrics.write_failed.fetch_add(1, Ordering::Relaxed);
                return false;
            }
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    }
    true
}

fn close(conns: &mut [Option<Conn>], slot: usize, live: &AtomicUsize, metrics: &Metrics) {
    if conns[slot].take().is_some() {
        live.fetch_sub(1, Ordering::Relaxed);
        metrics.conns_open.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::coordinator::engine::testutil::FixedEngine;
    use crate::coordinator::OffloadPolicy;
    use crate::server::protocol::Request;
    use crate::server::tcp::Client;
    use crate::simulator::Target;
    use std::io::BufRead;

    fn router() -> Router {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(Target::CpuSingle)))
            .build()
            .unwrap()
    }

    fn server() -> EventServer {
        EventServer::bind("127.0.0.1:0", router()).unwrap()
    }

    fn window() -> Vec<f32> {
        (0..30).map(|i| i as f32 / 30.0).collect()
    }

    #[test]
    fn json_round_trip_over_event_server() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();
        let outcome = client.classify(&window(), 1).unwrap();
        assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
        assert_eq!(outcome.target, "cpu");
        let session = client.open_session(None).unwrap();
        let (classes, logits) =
            client.classify_stream(session, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6], 2).unwrap();
        assert_eq!(classes, vec![1, 1]);
        assert_eq!(logits.len(), 2 * 6);
        assert_eq!(client.close_session(session).unwrap(), 2);
        client.set_load(0.4, 0.1).unwrap();
        let (gpu_util, _, metrics) = client.stats().unwrap();
        assert!((gpu_util - 0.4).abs() < 1e-9);
        assert_eq!(metrics.get("conns_open").as_usize(), Some(1));
        client.quit().unwrap();
        // Quit closed the connection server-side.
        assert!(client.ping().is_err());
    }

    #[test]
    fn binary_round_trip_over_event_server() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.negotiate_binary().unwrap();
        client.ping().unwrap();
        let outcome = client.classify(&window(), 7).unwrap();
        assert_eq!(outcome.class, 1);
        match client
            .call(&Request::ClassifyBatch { id: Some(3), windows: vec![window(), window()] })
            .unwrap()
        {
            Response::BatchResult { id, outcomes } => {
                assert_eq!(id, Some(3));
                assert_eq!(outcomes.len(), 2);
            }
            other => panic!("expected batch_result, got {other:?}"),
        }
        let session = client.open_session(None).unwrap();
        let (classes, _) = client.classify_stream(session, &[0.1, 0.2, 0.3], 4).unwrap();
        assert_eq!(classes, vec![1]);
        assert_eq!(client.close_session(session).unwrap(), 1);
        let (_, _, metrics) = client.stats().unwrap();
        assert_eq!(metrics.get("proto_v3_negotiated").as_usize(), Some(1));
        assert!(metrics.get("frames_rx").as_usize().unwrap() >= 6, "{metrics:?}");
        assert!(metrics.get("frames_tx").as_usize().unwrap() >= 5, "{metrics:?}");
        client.quit().unwrap();
    }

    #[test]
    fn pipelined_lines_are_answered_in_order() {
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client
            .writer
            .write_all(b"{\"type\":\"ping\"}\n{\"type\":\"stats\"}\n{\"type\":\"ping\"}\n")
            .unwrap();
        let mut line = String::new();
        for want in ["pong", "stats", "pong"] {
            line.clear();
            client.reader.read_line(&mut line).unwrap();
            let v = crate::json::parse(line.trim()).unwrap();
            assert_eq!(v.get("type").as_str(), Some(want), "{line}");
        }
    }

    #[test]
    fn hello_upgrade_handles_pipelined_binary_bytes() {
        // A client may send its hello line and its first frame in one
        // burst; the frame must wait in rbuf until the mode flips.
        let srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        let mut bytes = b"{\"type\":\"hello\",\"proto\":3}\n".to_vec();
        bytes.extend_from_slice(&frame::encode_request(&Request::Ping));
        client.writer.write_all(&bytes).unwrap();
        let mut line = String::new();
        client.reader.read_line(&mut line).unwrap();
        assert!(line.contains("hello_ok"), "{line}");
        let mut header = [0u8; frame::HEADER_LEN];
        client.reader.read_exact(&mut header).unwrap();
        let h = frame::parse_header(&header).unwrap();
        let mut payload = vec![0u8; h.payload_len as usize];
        client.reader.read_exact(&mut payload).unwrap();
        assert_eq!(frame::decode_response_body(&h, &payload).unwrap(), Response::Pong);
    }

    #[test]
    fn one_io_thread_multiplexes_many_connections() {
        let mut srv = EventServer::builder()
            .io_threads(1)
            .max_connections(256)
            .bind("127.0.0.1:0", router())
            .unwrap();
        let mut clients: Vec<Client> =
            (0..64).map(|_| Client::connect(srv.addr()).unwrap()).collect();
        // Half the fleet upgrades to binary; all stay multiplexed on
        // the single loop thread.
        for (i, c) in clients.iter_mut().enumerate() {
            if i % 2 == 0 {
                c.negotiate_binary().unwrap();
            }
        }
        for c in clients.iter_mut() {
            c.ping().unwrap();
        }
        for (i, c) in clients.iter_mut().enumerate() {
            assert_eq!(c.classify(&window(), i as u64).unwrap().class, 1);
        }
        assert_eq!(srv.connections_accepted(), 64);
        drop(clients);
        srv.stop();
    }

    #[test]
    fn cap_refuses_with_typed_error() {
        let mut srv = EventServer::builder()
            .max_connections(1)
            .bind("127.0.0.1:0", router())
            .unwrap();
        let mut c1 = Client::connect(srv.addr()).unwrap();
        c1.ping().unwrap();
        let mut c2 = Client::connect(srv.addr()).unwrap();
        match c2.call(&Request::Ping).unwrap() {
            Response::Error { code, message, .. } => {
                assert_eq!(code, ErrorCode::Overloaded);
                assert!(message.contains("max_connections"), "{message}");
            }
            other => panic!("expected overloaded refusal, got {other:?}"),
        }
        assert_eq!(srv.connections_accepted(), 1);
        assert_eq!(srv.connections_refused(), 1);
        drop(c2);
        srv.stop();
    }

    #[test]
    fn idle_timeout_says_bye_and_closes() {
        let srv = EventServer::builder()
            .idle_timeout(Duration::from_millis(50))
            .bind("127.0.0.1:0", router())
            .unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let mut line = String::new();
        client.reader.read_line(&mut line).unwrap();
        let v = crate::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("type").as_str(), Some("bye"), "{line}");
        line.clear();
        assert_eq!(client.reader.read_line(&mut line).unwrap(), 0, "closed after bye");
    }

    #[test]
    fn write_stall_deadline_closes_and_counts() {
        // A peer that pipelines huge-response requests and then never
        // reads jams the write backlog above the high-water mark, which
        // parks its reads. The stall deadline must reclaim the
        // connection (typed `overloaded` close is attempted best-effort
        // — with the peer's receive window full it rarely delivers)
        // instead of parking it forever.
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let fat = FixedEngine { num_classes: 256, ..FixedEngine::new(Target::CpuSingle) };
        let router = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(Target::CpuSingle))
            .max_wait(Duration::from_millis(1))
            .engine(Box::new(fat))
            .build()
            .unwrap();
        let metrics = Arc::clone(&router.metrics);
        let srv = EventServer::builder()
            .stall_timeout(Duration::from_millis(200))
            .bind("127.0.0.1:0", router)
            .unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        let session = client.open_session(None).unwrap();
        // Each chunk advances 8000 steps × 256 classes: a multi-megabyte
        // stream_result line. The writes may die mid-stream once the
        // stall fires and the server closes — that is the point.
        let frames = vec!["0.25"; 24_000].join(",");
        for i in 0..3 {
            let line = format!(
                "{{\"type\":\"classify_stream\",\"id\":{i},\"session\":{session},\"frames\":[{frames}]}}\n"
            );
            let _ = client.writer.write_all(line.as_bytes());
            let _ = client.writer.flush();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while metrics.conns_stalled.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "stall deadline never fired");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(metrics.conns_stalled.load(Ordering::Relaxed), 1);
        // The connection was closed server-side: draining what the
        // kernel already buffered must reach EOF, not block forever.
        let mut sink = vec![0u8; 1 << 16];
        loop {
            match client.reader.read(&mut sink) {
                Ok(0) => break,
                Ok(_) => {}
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
        // The server shrugged the stalled peer off; new clients work.
        let mut fresh = Client::connect(srv.addr()).unwrap();
        fresh.ping().unwrap();
    }

    #[test]
    fn binary_garbage_closes_but_malformed_payload_does_not() {
        let srv = server();
        // Valid header, malformed payload: typed error, connection
        // lives.
        let mut c1 = Client::connect(srv.addr()).unwrap();
        c1.negotiate_binary().unwrap();
        let payload = 99u32.to_le_bytes();
        let mut bad = vec![0xA7u8, 3, 0x05, 0];
        bad.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bad.extend_from_slice(&0u64.to_le_bytes());
        bad.extend_from_slice(&payload);
        c1.writer.write_all(&bad).unwrap();
        match c1.call(&Request::Ping) {
            // The error frame for the malformed payload arrives first.
            Ok(Response::Error { code, .. }) => assert_eq!(code, ErrorCode::BadRequest),
            other => panic!("expected typed error frame, got {other:?}"),
        }
        // Garbage where a header should be: framing lost, connection
        // dropped.
        let mut c2 = Client::connect(srv.addr()).unwrap();
        c2.negotiate_binary().unwrap();
        c2.writer.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        assert!(c2.call(&Request::Ping).is_err());
        // The server shrugged it all off.
        let mut c3 = Client::connect(srv.addr()).unwrap();
        c3.ping().unwrap();
    }

    #[test]
    fn proto_cap_keeps_connection_json() {
        let srv = EventServer::builder().max_proto(2).bind("127.0.0.1:0", router()).unwrap();
        let mut client = Client::connect(srv.addr()).unwrap();
        let err = client.negotiate_binary().unwrap_err().to_string();
        assert!(err.contains("unsupported_version"), "{err}");
        // The refusal is an answer, not a hang-up: JSON still works.
        client.ping().unwrap();
    }

    #[test]
    fn stop_with_live_clients_returns() {
        let mut srv = server();
        let mut client = Client::connect(srv.addr()).unwrap();
        client.ping().unwrap();
        srv.stop();
        assert!(client.ping().is_err(), "stopped server drops its connections");
    }
}
