//! Wire protocol: parse request lines, produce response values.
//!
//! Pure functions over [`crate::json::Value`] so the protocol is testable
//! without sockets; [`super::tcp`] adds the transport.

use crate::coordinator::Router;
use crate::json::{obj, Value};

/// A response line plus whether the connection should close.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub value: Value,
    pub close: bool,
}

fn err_response(id: Option<&Value>, msg: &str) -> Response {
    let mut fields = vec![
        ("type", Value::from("error")),
        ("message", Value::from(msg)),
    ];
    if let Some(id) = id {
        fields.push(("id", id.clone()));
    }
    Response { value: obj(fields), close: false }
}

/// Handle one request line against the router. Never panics on malformed
/// input — protocol errors become `{"type":"error"}` lines.
pub fn handle_message(router: &Router, line: &str) -> Response {
    let msg = match crate::json::parse(line) {
        Ok(v) => v,
        Err(e) => return err_response(None, &format!("bad json: {e}")),
    };
    let id = msg.as_obj().and_then(|o| o.get("id")).cloned();
    let id_ref = id.as_ref();
    match msg.get("type").as_str() {
        Some("ping") => Response { value: obj([("type", Value::from("pong"))]), close: false },
        Some("quit") => Response { value: obj([("type", Value::from("bye"))]), close: true },
        Some("stats") => {
            let mut v = router.metrics.to_json();
            if let Value::Obj(o) = &mut v {
                o.insert("type".into(), Value::from("stats"));
                o.insert("gpu_util".into(), Value::Num(router.device.gpu_util()));
                o.insert("cpu_util".into(), Value::Num(router.device.cpu_util()));
            }
            Response { value: v, close: false }
        }
        Some("set_load") => {
            if let Some(g) = msg.get("gpu").as_f64() {
                router.device.set_gpu_util(g);
            }
            if let Some(c) = msg.get("cpu").as_f64() {
                router.device.set_cpu_util(c);
            }
            Response { value: obj([("type", Value::from("ok"))]), close: false }
        }
        Some("classify") => {
            let Some(arr) = msg.get("window").as_arr() else {
                return err_response(id_ref, "classify requires a 'window' array");
            };
            let mut window = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_f64() {
                    Some(f) => window.push(f as f32),
                    None => return err_response(id_ref, "window must contain only numbers"),
                }
            }
            match router.classify(window) {
                Ok(reply) => {
                    let mut fields = vec![
                        ("type", Value::from("result")),
                        ("class", Value::from(reply.class)),
                        ("label", Value::from(reply.label.clone())),
                        ("sim_latency_us", Value::Num(reply.sim_ns as f64 / 1e3)),
                        ("wall_latency_us", Value::Num(reply.wall_ns as f64 / 1e3)),
                        ("target", Value::from(reply.target)),
                        ("batch_size", Value::from(reply.batch_size)),
                    ];
                    if let Some(id) = id_ref {
                        fields.push(("id", id.clone()));
                    }
                    Response { value: obj(fields), close: false }
                }
                Err(e) => err_response(id_ref, &format!("{e:#}")),
            }
        }
        Some(other) => err_response(id_ref, &format!("unknown type {other:?}")),
        None => err_response(id_ref, "missing 'type' field"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Manifest;
    use crate::coordinator::{DeviceState, OffloadPolicy, RouterConfig};
    use crate::runtime::Runtime;
    use crate::simulator::DeviceProfile;
    use std::time::Duration;

    fn router() -> Option<Router> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let man = Manifest::load(dir).unwrap();
        let rt = Runtime::start(&man).unwrap();
        Some(
            Router::start(
                &man,
                rt,
                DeviceState::new(DeviceProfile::nexus5()),
                RouterConfig {
                    policy: OffloadPolicy::CostModel,
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
            )
            .unwrap(),
        )
    }

    #[test]
    fn ping_pong_and_quit() {
        let Some(r) = router() else { return };
        let pong = handle_message(&r, r#"{"type":"ping"}"#);
        assert_eq!(pong.value.get("type").as_str(), Some("pong"));
        assert!(!pong.close);
        let bye = handle_message(&r, r#"{"type":"quit"}"#);
        assert!(bye.close);
    }

    #[test]
    fn malformed_lines_are_errors_not_panics() {
        let Some(r) = router() else { return };
        for bad in ["", "not json", "{}", r#"{"type":"nope"}"#,
                    r#"{"type":"classify"}"#,
                    r#"{"type":"classify","window":["a"]}"#,
                    r#"{"type":"classify","window":[1,2,3]}"#] {
            let resp = handle_message(&r, bad);
            assert_eq!(resp.value.get("type").as_str(), Some("error"), "{bad}");
            assert!(!resp.close);
        }
    }

    #[test]
    fn classify_round_trip_with_id() {
        let Some(r) = router() else { return };
        let ds = crate::har::generate(1, 23);
        let window: Vec<String> = ds.window(0).iter().map(|v| format!("{v}")).collect();
        let line = format!(
            r#"{{"type":"classify","id":42,"window":[{}]}}"#,
            window.join(",")
        );
        let resp = handle_message(&r, &line);
        assert_eq!(resp.value.get("type").as_str(), Some("result"), "{:?}", resp.value);
        assert_eq!(resp.value.get("id").as_usize(), Some(42));
        assert!(resp.value.get("class").as_usize().unwrap() < 6);
        assert!(resp.value.get("sim_latency_us").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn set_load_then_stats_reflects() {
        let Some(r) = router() else { return };
        let ok = handle_message(&r, r#"{"type":"set_load","gpu":0.75,"cpu":0.2}"#);
        assert_eq!(ok.value.get("type").as_str(), Some("ok"));
        let stats = handle_message(&r, r#"{"type":"stats"}"#);
        assert_eq!(stats.value.get("gpu_util").as_f64(), Some(0.75));
        assert_eq!(stats.value.get("cpu_util").as_f64(), Some(0.2));
    }
}
