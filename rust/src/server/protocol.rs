//! Wire protocol v2: typed [`Request`]/[`Response`] messages.
//!
//! Every message is a variant of the two enums below, converted to and
//! from JSON through the [`ToValue`]/[`FromValue`] codec traits — no
//! call site assembles protocol JSON by hand, and malformed input is
//! handled in exactly one tested place. Responses carry the protocol
//! version (`"v": 2`); requests may state a version and are rejected
//! when it does not match. The full message catalogue is documented in
//! DESIGN.md §7.
//!
//! Pure functions over [`crate::json::Value`] so the protocol is
//! testable without sockets; [`super::tcp`] adds the transport.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{
    parse_target, ClassifyOptions, Precision, ReplySink, Router, ServeError, ServeReply,
    StreamReply,
};
use crate::json::{obj, CodecError, FromValue, ToValue, Value};
use crate::simulator::Target;

/// Version stamped on every response; requests carrying a different
/// `"v"` are rejected with [`ErrorCode::UnsupportedVersion`].
pub const PROTOCOL_VERSION: u64 = 2;

/// `hello` negotiation value for the default transport: line-delimited
/// JSON (this module's codec).
pub const PROTO_V2_JSON: u64 = 2;

/// `hello` negotiation value for the binary transport: length-prefixed
/// frames ([`super::frame`], DESIGN.md §12). A client upgrades by
/// sending a JSON `hello {"proto":3}`; after the server's `hello_ok`
/// both directions switch to frames on the same connection.
pub const PROTO_V3_BINARY: u64 = 3;

/// Machine-readable error class carried by [`Response::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The line was not valid JSON.
    BadJson,
    /// Valid JSON, but not a well-formed request (unknown type, missing
    /// or mistyped fields, wrong window length, empty batch, ...).
    BadRequest,
    /// The request declared a protocol version we do not speak.
    UnsupportedVersion,
    /// `set_load` utilization outside `[0, 1]`.
    InvalidLoad,
    /// The caller's deadline elapsed before a reply was ready.
    Deadline,
    /// Execution failed in every registered engine.
    Engine,
    /// Load shed: the scheduler's admission queue (or the server's
    /// connection cap) was full; retry later or elsewhere.
    Overloaded,
    /// The stream referenced a session id the store has never seen (or
    /// one already closed).
    SessionNotFound,
    /// The session existed but idled past its TTL and was evicted; the
    /// client must `open_session` again (state is gone).
    SessionExpired,
    /// The request's deadline budget was consumed by failover retries
    /// before any engine answered (DESIGN.md §15) — a typed terminal
    /// outcome, never a hang or a silent drop.
    RetriesExhausted,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadJson => "bad_json",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::InvalidLoad => "invalid_load",
            ErrorCode::Deadline => "deadline",
            ErrorCode::Engine => "engine",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::SessionNotFound => "session_not_found",
            ErrorCode::SessionExpired => "session_expired",
            ErrorCode::RetriesExhausted => "retries_exhausted",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "bad_json" => Some(ErrorCode::BadJson),
            "bad_request" => Some(ErrorCode::BadRequest),
            "unsupported_version" => Some(ErrorCode::UnsupportedVersion),
            "invalid_load" => Some(ErrorCode::InvalidLoad),
            "deadline" => Some(ErrorCode::Deadline),
            "engine" => Some(ErrorCode::Engine),
            "overloaded" => Some(ErrorCode::Overloaded),
            "session_not_found" => Some(ErrorCode::SessionNotFound),
            "session_expired" => Some(ErrorCode::SessionExpired),
            "retries_exhausted" => Some(ErrorCode::RetriesExhausted),
            _ => None,
        }
    }
}

/// The typed wire code for a serving-side failure.
/// The refusal a server capped below a client's requested proto sends
/// (`mobirnn serve --proto 2`): typed, with the cap in the message.
pub(crate) fn proto_capped_error(max_proto: u64) -> Response {
    Response::Error {
        id: None,
        code: ErrorCode::UnsupportedVersion,
        message: format!("server accepts proto <= {max_proto}"),
    }
}

pub(crate) fn serve_error_code(e: &ServeError) -> ErrorCode {
    match e {
        ServeError::DeadlineExceeded => ErrorCode::Deadline,
        ServeError::Overloaded => ErrorCode::Overloaded,
        ServeError::EngineFailure(_) => ErrorCode::Engine,
        ServeError::SessionNotFound(_) => ErrorCode::SessionNotFound,
        ServeError::SessionExpired(_) => ErrorCode::SessionExpired,
        ServeError::RetriesExhausted => ErrorCode::RetriesExhausted,
    }
}

/// A client → server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    Quit,
    Stats,
    /// Set background device utilization (the Fig 7 knobs). Values must
    /// lie in `[0, 1]`; out-of-range input is rejected with a typed
    /// error, never silently accepted.
    SetLoad { id: Option<u64>, gpu: Option<f64>, cpu: Option<f64> },
    /// Classify one flat `[seq_len * input_dim]` window.
    Classify {
        id: Option<u64>,
        window: Vec<f32>,
        /// Per-request target override ("gpu" | "cpu" | "cpu-multi" | ...).
        target: Option<Target>,
        /// Numeric precision ("f32" | "int8"): int8 opts into the
        /// quantized engine (DESIGN.md §10); absent means f32.
        precision: Option<Precision>,
        /// Reply deadline in milliseconds.
        deadline_ms: Option<u64>,
        /// Opt into brownout degradation (DESIGN.md §15): when every f32
        /// pool's breaker is open the scheduler may serve this request
        /// from the int8 tier, marking the result `degraded: "int8"`.
        /// Absent means false — never degrade without consent.
        allow_degraded: bool,
    },
    /// Classify several windows in one round trip; they enter the
    /// batcher together.
    ClassifyBatch { id: Option<u64>, windows: Vec<Vec<f32>> },
    /// Open a streaming session (DESIGN.md §11): allocates persistent
    /// h/c state server-side and pins the session to an engine pool.
    /// Absent precision means f32; int8 pins to the quant pool.
    OpenSession { id: Option<u64>, precision: Option<Precision> },
    /// Advance a session through flat `[steps, input_dim]` frames (one
    /// or more timesteps) and get per-step classes + logits back.
    ClassifyStream { id: Option<u64>, session: u64, frames: Vec<f32> },
    /// Close a session, freeing its state immediately (instead of
    /// waiting for TTL eviction).
    CloseSession { id: Option<u64>, session: u64 },
    /// Negotiate the wire transport for this connection
    /// ([`PROTO_V2_JSON`] | [`PROTO_V3_BINARY`]); always sent as JSON.
    Hello { proto: u64 },
}

/// A server → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    /// Connection will close after this line.
    Bye,
    /// `set_load` applied; echoes the utilizations now in effect.
    LoadSet { id: Option<u64>, gpu: f64, cpu: f64 },
    Stats { gpu_util: f64, cpu_util: f64, metrics: Value },
    Result { id: Option<u64>, outcome: ClassifyOutcome },
    BatchResult { id: Option<u64>, outcomes: Vec<ClassifyOutcome> },
    /// `open_session` succeeded; carries the new session id, the pool it
    /// is pinned to, and the idle TTL the client must stay inside.
    SessionOpened { id: Option<u64>, session: u64, target: String, ttl_ms: u64 },
    /// Per-step results for one `classify_stream` chunk: `classes[t]`
    /// and `logits[t*C..(t+1)*C]` are the prediction after step `t`.
    StreamResult {
        id: Option<u64>,
        session: u64,
        steps: usize,
        classes: Vec<usize>,
        logits: Vec<f32>,
        wall_latency_us: f64,
        target: String,
    },
    /// `close_session` succeeded; echoes the total steps the session
    /// consumed over its lifetime.
    SessionClosed { id: Option<u64>, session: u64, steps: u64 },
    /// `hello` accepted; echoes the protocol now in effect. After a
    /// `proto: 3` acknowledgement both sides speak binary frames.
    HelloOk { proto: u64 },
    Error { id: Option<u64>, code: ErrorCode, message: String },
}

/// One classification result as carried on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassifyOutcome {
    pub class: usize,
    pub label: String,
    pub sim_latency_us: f64,
    pub wall_latency_us: f64,
    pub target: String,
    pub batch_size: usize,
    /// `Some("int8")` when the scheduler served this request from the
    /// quantized tier under brownout (DESIGN.md §15); absent otherwise.
    pub degraded: Option<String>,
}

impl ClassifyOutcome {
    pub fn from_reply(r: &ServeReply) -> Self {
        Self {
            class: r.class,
            label: r.label.clone(),
            sim_latency_us: r.sim_ns as f64 / 1e3,
            wall_latency_us: r.wall_ns as f64 / 1e3,
            target: r.target.to_string(),
            batch_size: r.batch_size,
            degraded: r.degraded.map(str::to_string),
        }
    }

    fn fields(&self) -> Vec<(&'static str, Value)> {
        let mut fields = vec![
            ("class", Value::from(self.class)),
            ("label", Value::from(self.label.clone())),
            ("sim_latency_us", Value::Num(self.sim_latency_us)),
            ("wall_latency_us", Value::Num(self.wall_latency_us)),
            ("target", Value::from(self.target.clone())),
            ("batch_size", Value::from(self.batch_size)),
        ];
        if let Some(d) = &self.degraded {
            fields.push(("degraded", Value::from(d.clone())));
        }
        fields
    }
}

impl ToValue for ClassifyOutcome {
    fn to_value(&self) -> Value {
        obj(self.fields())
    }
}

impl FromValue for ClassifyOutcome {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        Ok(Self {
            class: field(v, "class")?,
            label: field(v, "label")?,
            sim_latency_us: field(v, "sim_latency_us")?,
            wall_latency_us: field(v, "wall_latency_us")?,
            target: field(v, "target")?,
            batch_size: field(v, "batch_size")?,
            degraded: field(v, "degraded")?,
        })
    }
}

// ---- field helpers ---------------------------------------------------

/// Decode object field `key` through its [`FromValue`] codec, wrapping
/// failures with the field name. Absent fields decode as `Value::Null`,
/// so `Option<T>` makes a field optional and a bare `T` requires it.
fn field<T: FromValue>(v: &Value, key: &str) -> Result<T, CodecError> {
    T::from_value(v.get(key)).map_err(|e| CodecError::field(key, e))
}

/// Best-effort id for echoing on error responses built before a request
/// decoded; strict decoding uses `field::<Option<u64>>(v, "id")`.
fn read_id(v: &Value) -> Option<u64> {
    v.get("id").as_usize().map(|u| u as u64)
}

fn envelope(ty: &'static str, id: Option<u64>) -> Vec<(&'static str, Value)> {
    let mut fields = vec![("type", Value::from(ty)), ("v", Value::from(PROTOCOL_VERSION))];
    if let Some(id) = id {
        fields.push(("id", Value::from(id)));
    }
    fields
}

// ---- Request codec ---------------------------------------------------

impl ToValue for Request {
    fn to_value(&self) -> Value {
        match self {
            Request::Ping => obj(envelope("ping", None)),
            Request::Quit => obj(envelope("quit", None)),
            Request::Stats => obj(envelope("stats", None)),
            Request::SetLoad { id, gpu, cpu } => {
                let mut fields = envelope("set_load", *id);
                if let Some(g) = gpu {
                    fields.push(("gpu", Value::Num(*g)));
                }
                if let Some(c) = cpu {
                    fields.push(("cpu", Value::Num(*c)));
                }
                obj(fields)
            }
            Request::Classify { id, window, target, precision, deadline_ms, allow_degraded } => {
                let mut fields = envelope("classify", *id);
                fields.push(("window", window.to_value()));
                if let Some(t) = target {
                    fields.push(("target", Value::from(crate::coordinator::target_label(*t))));
                }
                if let Some(p) = precision {
                    fields.push(("precision", Value::from(p.as_str())));
                }
                if let Some(d) = deadline_ms {
                    fields.push(("deadline_ms", Value::from(*d)));
                }
                if *allow_degraded {
                    fields.push(("allow_degraded", Value::Bool(true)));
                }
                obj(fields)
            }
            Request::ClassifyBatch { id, windows } => {
                let mut fields = envelope("classify_batch", *id);
                fields.push(("windows", windows.to_value()));
                obj(fields)
            }
            Request::OpenSession { id, precision } => {
                let mut fields = envelope("open_session", *id);
                if let Some(p) = precision {
                    fields.push(("precision", Value::from(p.as_str())));
                }
                obj(fields)
            }
            Request::ClassifyStream { id, session, frames } => {
                let mut fields = envelope("classify_stream", *id);
                fields.push(("session", Value::from(*session)));
                fields.push(("frames", frames.to_value()));
                obj(fields)
            }
            Request::CloseSession { id, session } => {
                let mut fields = envelope("close_session", *id);
                fields.push(("session", Value::from(*session)));
                obj(fields)
            }
            Request::Hello { proto } => {
                let mut fields = envelope("hello", None);
                fields.push(("proto", Value::from(*proto)));
                obj(fields)
            }
        }
    }
}

impl FromValue for Request {
    // Version enforcement lives in `handle_line` (the transport), which
    // checks `"v"` before decoding so the mismatch gets its own typed
    // error code; the codec itself is version-agnostic.
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        let ty = v
            .get("type")
            .as_str()
            .ok_or_else(|| CodecError::new("missing 'type' field"))?;
        match ty {
            "ping" => Ok(Request::Ping),
            "quit" => Ok(Request::Quit),
            "stats" => Ok(Request::Stats),
            "set_load" => Ok(Request::SetLoad {
                id: field(v, "id")?,
                gpu: field(v, "gpu")?,
                cpu: field(v, "cpu")?,
            }),
            "classify" => {
                let target = match v.get("target") {
                    Value::Null => None,
                    t => {
                        let label = t
                            .as_str()
                            .ok_or_else(|| CodecError::field("target", "expected a string"))?;
                        Some(parse_target(label).ok_or_else(|| {
                            CodecError::field("target", format!("unknown target {label:?}"))
                        })?)
                    }
                };
                let precision = match v.get("precision") {
                    Value::Null => None,
                    p => {
                        let label = p
                            .as_str()
                            .ok_or_else(|| CodecError::field("precision", "expected a string"))?;
                        Some(Precision::parse(label).ok_or_else(|| {
                            CodecError::field("precision", format!("unknown precision {label:?}"))
                        })?)
                    }
                };
                Ok(Request::Classify {
                    id: field(v, "id")?,
                    window: field(v, "window")?,
                    target,
                    precision,
                    deadline_ms: field(v, "deadline_ms")?,
                    allow_degraded: field::<Option<bool>>(v, "allow_degraded")?
                        .unwrap_or(false),
                })
            }
            "classify_batch" => Ok(Request::ClassifyBatch {
                id: field(v, "id")?,
                windows: field(v, "windows")?,
            }),
            "open_session" => {
                let precision = match v.get("precision") {
                    Value::Null => None,
                    p => {
                        let label = p
                            .as_str()
                            .ok_or_else(|| CodecError::field("precision", "expected a string"))?;
                        Some(Precision::parse(label).ok_or_else(|| {
                            CodecError::field("precision", format!("unknown precision {label:?}"))
                        })?)
                    }
                };
                Ok(Request::OpenSession { id: field(v, "id")?, precision })
            }
            "classify_stream" => Ok(Request::ClassifyStream {
                id: field(v, "id")?,
                session: field(v, "session")?,
                frames: field(v, "frames")?,
            }),
            "close_session" => Ok(Request::CloseSession {
                id: field(v, "id")?,
                session: field(v, "session")?,
            }),
            "hello" => Ok(Request::Hello { proto: field(v, "proto")? }),
            other => Err(CodecError::new(format!("unknown type {other:?}"))),
        }
    }
}

// ---- Response codec --------------------------------------------------

impl ToValue for Response {
    fn to_value(&self) -> Value {
        match self {
            Response::Pong => obj(envelope("pong", None)),
            Response::Bye => obj(envelope("bye", None)),
            Response::LoadSet { id, gpu, cpu } => {
                let mut fields = envelope("load_set", *id);
                fields.push(("gpu", Value::Num(*gpu)));
                fields.push(("cpu", Value::Num(*cpu)));
                obj(fields)
            }
            Response::Stats { gpu_util, cpu_util, metrics } => {
                let mut fields = envelope("stats", None);
                fields.push(("gpu_util", Value::Num(*gpu_util)));
                fields.push(("cpu_util", Value::Num(*cpu_util)));
                fields.push(("metrics", metrics.clone()));
                obj(fields)
            }
            Response::Result { id, outcome } => {
                let mut fields = envelope("result", *id);
                fields.extend(outcome.fields());
                obj(fields)
            }
            Response::BatchResult { id, outcomes } => {
                let mut fields = envelope("batch_result", *id);
                fields.push(("results", outcomes.to_value()));
                obj(fields)
            }
            Response::SessionOpened { id, session, target, ttl_ms } => {
                let mut fields = envelope("session_opened", *id);
                fields.push(("session", Value::from(*session)));
                fields.push(("target", Value::from(target.clone())));
                fields.push(("ttl_ms", Value::from(*ttl_ms)));
                obj(fields)
            }
            Response::StreamResult {
                id,
                session,
                steps,
                classes,
                logits,
                wall_latency_us,
                target,
            } => {
                let mut fields = envelope("stream_result", *id);
                fields.push(("session", Value::from(*session)));
                fields.push(("steps", Value::from(*steps)));
                fields.push(("classes", classes.to_value()));
                fields.push(("logits", logits.to_value()));
                fields.push(("wall_latency_us", Value::Num(*wall_latency_us)));
                fields.push(("target", Value::from(target.clone())));
                obj(fields)
            }
            Response::SessionClosed { id, session, steps } => {
                let mut fields = envelope("session_closed", *id);
                fields.push(("session", Value::from(*session)));
                fields.push(("steps", Value::from(*steps)));
                obj(fields)
            }
            Response::HelloOk { proto } => {
                let mut fields = envelope("hello_ok", None);
                fields.push(("proto", Value::from(*proto)));
                obj(fields)
            }
            Response::Error { id, code, message } => {
                let mut fields = envelope("error", *id);
                fields.push(("code", Value::from(code.as_str())));
                fields.push(("message", Value::from(message.clone())));
                obj(fields)
            }
        }
    }
}

impl FromValue for Response {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        let ty = v
            .get("type")
            .as_str()
            .ok_or_else(|| CodecError::new("missing 'type' field"))?;
        match ty {
            "pong" => Ok(Response::Pong),
            "bye" => Ok(Response::Bye),
            "load_set" => Ok(Response::LoadSet {
                id: field(v, "id")?,
                gpu: field(v, "gpu")?,
                cpu: field(v, "cpu")?,
            }),
            "stats" => {
                let metrics = v.get("metrics");
                if metrics.as_obj().is_none() {
                    return Err(CodecError::field("metrics", "expected an object"));
                }
                Ok(Response::Stats {
                    gpu_util: field(v, "gpu_util")?,
                    cpu_util: field(v, "cpu_util")?,
                    metrics: metrics.clone(),
                })
            }
            "result" => Ok(Response::Result {
                id: read_id(v),
                outcome: ClassifyOutcome::from_value(v)?,
            }),
            "batch_result" => Ok(Response::BatchResult {
                id: read_id(v),
                outcomes: Vec::<ClassifyOutcome>::from_value(v.get("results"))
                    .map_err(|e| CodecError::field("results", e))?,
            }),
            "session_opened" => Ok(Response::SessionOpened {
                id: read_id(v),
                session: field(v, "session")?,
                target: field(v, "target")?,
                ttl_ms: field(v, "ttl_ms")?,
            }),
            "stream_result" => Ok(Response::StreamResult {
                id: read_id(v),
                session: field(v, "session")?,
                steps: field(v, "steps")?,
                classes: field(v, "classes")?,
                logits: field(v, "logits")?,
                wall_latency_us: field(v, "wall_latency_us")?,
                target: field(v, "target")?,
            }),
            "session_closed" => Ok(Response::SessionClosed {
                id: read_id(v),
                session: field(v, "session")?,
                steps: field(v, "steps")?,
            }),
            "hello_ok" => Ok(Response::HelloOk { proto: field(v, "proto")? }),
            "error" => {
                let code_str: String = field(v, "code")?;
                let code = ErrorCode::parse(&code_str)
                    .ok_or_else(|| CodecError::field("code", format!("unknown code {code_str:?}")))?;
                Ok(Response::Error { id: read_id(v), code, message: field(v, "message")? })
            }
            other => Err(CodecError::new(format!("unknown type {other:?}"))),
        }
    }
}

// ---- server-side execution -------------------------------------------

/// Decode one wire line into a typed request, applying the same
/// version and error rules as [`handle_line`]. `Err` carries the ready
/// [`Response::Error`] — the transports (threaded and event-driven)
/// share this single decode seam.
pub fn decode_line(line: &str) -> Result<Request, Response> {
    let v = match crate::json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Err(Response::Error {
                id: None,
                code: ErrorCode::BadJson,
                message: format!("bad json: {e}"),
            })
        }
    };
    let id = read_id(&v);
    if let Some(ver) = v.get("v").as_usize() {
        if ver as u64 != PROTOCOL_VERSION {
            return Err(Response::Error {
                id,
                code: ErrorCode::UnsupportedVersion,
                message: format!(
                    "protocol version {ver} not supported (server speaks v{PROTOCOL_VERSION})"
                ),
            });
        }
    }
    Request::from_value(&v)
        .map_err(|e| Response::Error { id, code: ErrorCode::BadRequest, message: e.to_string() })
}

/// Handle one wire line against the router. Never panics on malformed
/// input — protocol and execution errors become typed
/// [`Response::Error`] lines.
pub fn handle_line(router: &Router, line: &str) -> Response {
    match decode_line(line) {
        Ok(req) => handle_request(router, req),
        Err(resp) => resp,
    }
}

/// Execute a typed request against the router.
pub fn handle_request(router: &Router, req: Request) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Quit => Response::Bye,
        Request::Stats => Response::Stats {
            gpu_util: router.device.gpu_util(),
            cpu_util: router.device.cpu_util(),
            metrics: router.metrics.to_json(),
        },
        Request::SetLoad { id, gpu, cpu } => {
            for u in [gpu, cpu].into_iter().flatten() {
                if !(0.0..=1.0).contains(&u) {
                    return Response::Error {
                        id,
                        code: ErrorCode::InvalidLoad,
                        message: format!("utilization {u} outside [0, 1]"),
                    };
                }
            }
            if let Some(g) = gpu {
                router.device.set_gpu_util(g);
            }
            if let Some(c) = cpu {
                router.device.set_cpu_util(c);
            }
            Response::LoadSet {
                id,
                gpu: router.device.gpu_util(),
                cpu: router.device.cpu_util(),
            }
        }
        Request::Classify { id, window, target, precision, deadline_ms, allow_degraded } => {
            let expect = router.window_len();
            if window.len() != expect {
                return Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!("window has {} values, expected {expect}", window.len()),
                };
            }
            let opts = ClassifyOptions {
                id,
                target,
                precision,
                deadline: deadline_ms.map(Duration::from_millis),
                allow_degraded,
            };
            match router.classify_with(window, opts) {
                Ok(reply) => {
                    Response::Result { id, outcome: ClassifyOutcome::from_reply(&reply) }
                }
                Err(e) => {
                    let code = e
                        .downcast_ref::<ServeError>()
                        .map_or(ErrorCode::Engine, serve_error_code);
                    Response::Error { id, code, message: format!("{e:#}") }
                }
            }
        }
        Request::ClassifyBatch { id, windows } => {
            if windows.is_empty() {
                return Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: "classify_batch requires at least one window".into(),
                };
            }
            let expect = router.window_len();
            if let Some(w) = windows.iter().find(|w| w.len() != expect) {
                return Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!("window has {} values, expected {expect}", w.len()),
                };
            }
            // Submit everything first so the windows batch together.
            let mut rxs = Vec::with_capacity(windows.len());
            for w in windows {
                match router.submit(w) {
                    Ok(rx) => rxs.push(rx),
                    Err(e) => {
                        return Response::Error {
                            id,
                            code: ErrorCode::Engine,
                            message: format!("{e:#}"),
                        }
                    }
                }
            }
            let mut outcomes = Vec::with_capacity(rxs.len());
            for rx in rxs {
                match rx.recv() {
                    Ok(Ok(reply)) => outcomes.push(ClassifyOutcome::from_reply(&reply)),
                    Ok(Err(e)) => {
                        return Response::Error {
                            id,
                            code: serve_error_code(&e),
                            message: e.to_string(),
                        }
                    }
                    Err(_) => {
                        return Response::Error {
                            id,
                            code: ErrorCode::Engine,
                            message: "router dropped reply".into(),
                        }
                    }
                }
            }
            Response::BatchResult { id, outcomes }
        }
        Request::OpenSession { id, precision } => {
            match router.open_session(precision.unwrap_or(Precision::F32)) {
                Ok(info) => Response::SessionOpened {
                    id,
                    session: info.id,
                    target: info.target.to_string(),
                    ttl_ms: info.ttl.as_millis() as u64,
                },
                Err(e) => {
                    let code = e
                        .downcast_ref::<ServeError>()
                        .map_or(ErrorCode::BadRequest, serve_error_code);
                    Response::Error { id, code, message: format!("{e:#}") }
                }
            }
        }
        Request::ClassifyStream { id, session, frames } => {
            let dim = router.shape().input_dim;
            if frames.is_empty() || frames.len() % dim != 0 {
                return Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!(
                        "frames has {} values, expected a positive multiple of input_dim {dim}",
                        frames.len()
                    ),
                };
            }
            match router.classify_stream(session, frames, id) {
                Ok(reply) => stream_result(id, &reply),
                Err(e) => {
                    let code = e
                        .downcast_ref::<ServeError>()
                        .map_or(ErrorCode::Engine, serve_error_code);
                    Response::Error { id, code, message: format!("{e:#}") }
                }
            }
        }
        Request::CloseSession { id, session } => match router.close_session(session) {
            Ok(steps) => Response::SessionClosed { id, session, steps },
            Err(e) => {
                let code = e
                    .downcast_ref::<ServeError>()
                    .map_or(ErrorCode::Engine, serve_error_code);
                Response::Error { id, code, message: format!("{e:#}") }
            }
        },
        Request::Hello { proto } => match proto {
            PROTO_V2_JSON => Response::HelloOk { proto },
            PROTO_V3_BINARY => {
                router.metrics.proto_v3_negotiated.fetch_add(1, Ordering::Relaxed);
                Response::HelloOk { proto }
            }
            _ => Response::Error {
                id: None,
                code: ErrorCode::UnsupportedVersion,
                message: format!(
                    "wire protocol {proto} not supported (server speaks \
                     {PROTO_V2_JSON} and {PROTO_V3_BINARY})"
                ),
            },
        },
    }
}

/// Execute a typed request without ever blocking the calling thread.
///
/// Synchronous ops (ping, stats, set_load, session open/close, hello)
/// run inline, so `done` fires before this returns. The classify family
/// is handed to the scheduler with a [`ReplySink`] callback and `done`
/// fires later, on whichever pool worker resolves the request. Exactly
/// one `done` call happens per request — the event-driven server
/// (DESIGN.md §12) relies on that to keep its per-connection in-flight
/// accounting balanced. Unlike the blocking path, reply deadlines are
/// enforced only at dispatch (expired-in-queue drops), never by a
/// waiting thread — there is none.
pub fn handle_request_async(router: &Router, req: Request, done: Box<dyn FnOnce(Response) + Send>) {
    match req {
        Request::Classify { id, window, target, precision, deadline_ms, allow_degraded } => {
            let expect = router.window_len();
            if window.len() != expect {
                done(Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!("window has {} values, expected {expect}", window.len()),
                });
                return;
            }
            let opts = ClassifyOptions {
                id,
                target,
                precision,
                deadline: deadline_ms.map(Duration::from_millis),
                allow_degraded,
            };
            let sink = ReplySink::callback(move |outcome: Result<ServeReply, ServeError>| {
                done(match outcome {
                    Ok(reply) => {
                        Response::Result { id, outcome: ClassifyOutcome::from_reply(&reply) }
                    }
                    Err(e) => Response::Error {
                        id,
                        code: serve_error_code(&e),
                        message: e.to_string(),
                    },
                })
            });
            // Cannot fail: the window was validated above with the same
            // rule `submit_sink` applies.
            let _ = router.submit_sink(window, opts, sink);
        }
        Request::ClassifyBatch { id, windows } => {
            if windows.is_empty() {
                done(Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: "classify_batch requires at least one window".into(),
                });
                return;
            }
            let expect = router.window_len();
            if let Some(w) = windows.iter().find(|w| w.len() != expect) {
                done(Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!("window has {} values, expected {expect}", w.len()),
                });
                return;
            }
            // Fan-in: one slot per window (submit order preserved); the
            // last reply to land assembles the batch response.
            let n = windows.len();
            let slots: Arc<Mutex<Vec<Option<Result<ServeReply, ServeError>>>>> =
                Arc::new(Mutex::new((0..n).map(|_| None).collect()));
            let remaining = Arc::new(AtomicUsize::new(n));
            let done = Arc::new(Mutex::new(Some(done)));
            for (i, w) in windows.into_iter().enumerate() {
                let slots = Arc::clone(&slots);
                let remaining = Arc::clone(&remaining);
                let done = Arc::clone(&done);
                let sink = ReplySink::callback(move |outcome| {
                    if let Ok(mut s) = slots.lock() {
                        s[i] = Some(outcome);
                    }
                    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        let filled = slots
                            .lock()
                            .map(|mut s| std::mem::take(&mut *s))
                            .unwrap_or_default();
                        if let Some(done) = done.lock().ok().and_then(|mut d| d.take()) {
                            done(batch_response(id, filled));
                        }
                    }
                });
                // Cannot fail: every window was validated above.
                let _ = router.submit_sink(w, ClassifyOptions::default(), sink);
            }
        }
        Request::ClassifyStream { id, session, frames } => {
            let dim = router.shape().input_dim;
            if frames.is_empty() || frames.len() % dim != 0 {
                done(Response::Error {
                    id,
                    code: ErrorCode::BadRequest,
                    message: format!(
                        "frames has {} values, expected a positive multiple of input_dim {dim}",
                        frames.len()
                    ),
                });
                return;
            }
            let sink = ReplySink::callback(move |outcome: Result<StreamReply, ServeError>| {
                done(match outcome {
                    Ok(reply) => stream_result(id, &reply),
                    Err(e) => Response::Error {
                        id,
                        code: serve_error_code(&e),
                        message: e.to_string(),
                    },
                })
            });
            // Cannot fail: the chunk shape was validated above.
            let _ = router.submit_stream_sink(session, frames, id, sink);
        }
        other => done(handle_request(router, other)),
    }
}

/// Assemble the fan-in result of an async batch: the first failed slot
/// (in submit order) becomes the whole batch's error, matching the
/// blocking path in [`handle_request`].
fn batch_response(
    id: Option<u64>,
    slots: Vec<Option<Result<ServeReply, ServeError>>>,
) -> Response {
    let mut outcomes = Vec::with_capacity(slots.len());
    for slot in slots {
        match slot {
            Some(Ok(reply)) => outcomes.push(ClassifyOutcome::from_reply(&reply)),
            Some(Err(e)) => {
                return Response::Error {
                    id,
                    code: serve_error_code(&e),
                    message: e.to_string(),
                }
            }
            None => {
                return Response::Error {
                    id,
                    code: ErrorCode::Engine,
                    message: "router dropped reply".into(),
                }
            }
        }
    }
    Response::BatchResult { id, outcomes }
}

/// The wire form of a [`StreamReply`].
fn stream_result(id: Option<u64>, r: &StreamReply) -> Response {
    Response::StreamResult {
        id,
        session: r.session,
        steps: r.steps,
        classes: r.classes.clone(),
        logits: r.logits.clone(),
        wall_latency_us: r.wall_ns as f64 / 1e3,
        target: r.target.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::coordinator::engine::testutil::FixedEngine;
    use crate::coordinator::OffloadPolicy;
    use crate::simulator::Factorization;

    /// Protocol tests run against a fake-engine router — no artifacts
    /// needed, so they always execute.
    fn router() -> Router {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(crate::simulator::Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(crate::simulator::Target::CpuSingle)))
            .build()
            .unwrap()
    }

    fn window_json(n: usize) -> String {
        let vals: Vec<String> = (0..n).map(|i| format!("{}", i as f64 / 10.0)).collect();
        format!("[{}]", vals.join(","))
    }

    #[test]
    fn every_request_variant_round_trips() {
        let cases = vec![
            Request::Ping,
            Request::Quit,
            Request::Stats,
            Request::SetLoad { id: Some(11), gpu: Some(0.5), cpu: None },
            Request::SetLoad { id: None, gpu: None, cpu: Some(1.0) },
            Request::Classify {
                id: Some(7),
                window: vec![0.25, -1.5, 0.0],
                target: Some(crate::simulator::Target::CpuMulti(4)),
                precision: None,
                deadline_ms: Some(250),
                allow_degraded: false,
            },
            Request::Classify {
                id: Some(8),
                window: vec![1.0],
                target: None,
                precision: Some(Precision::Int8),
                deadline_ms: None,
                allow_degraded: false,
            },
            Request::Classify {
                id: None,
                window: vec![],
                target: None,
                precision: Some(Precision::F32),
                deadline_ms: None,
                allow_degraded: true,
            },
            Request::Classify {
                id: None,
                window: vec![],
                target: None,
                precision: None,
                deadline_ms: None,
                allow_degraded: false,
            },
            Request::ClassifyBatch {
                id: Some(1),
                windows: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            },
            Request::OpenSession { id: Some(12), precision: None },
            Request::OpenSession { id: None, precision: Some(Precision::Int8) },
            Request::ClassifyStream { id: Some(13), session: 7, frames: vec![0.5, -0.25, 1.0] },
            Request::CloseSession { id: None, session: 7 },
            Request::Hello { proto: PROTO_V3_BINARY },
            Request::Hello { proto: PROTO_V2_JSON },
        ];
        for req in cases {
            // Value round-trip.
            assert_eq!(Request::from_value(&req.to_value()).unwrap(), req, "{req:?}");
            // Wire-text round-trip.
            let line = req.to_value().to_json();
            let back = Request::from_value(&crate::json::parse(&line).unwrap()).unwrap();
            assert_eq!(back, req, "{line}");
        }
    }

    #[test]
    fn every_response_variant_round_trips() {
        let outcome = ClassifyOutcome {
            class: 3,
            label: "sitting".into(),
            sim_latency_us: 1234.5,
            wall_latency_us: 88.25,
            target: "gpu".into(),
            batch_size: 4,
            degraded: None,
        };
        let cases = vec![
            Response::Pong,
            Response::Bye,
            Response::LoadSet { id: Some(4), gpu: 0.75, cpu: 0.25 },
            Response::LoadSet { id: None, gpu: 0.0, cpu: 1.0 },
            Response::Stats {
                gpu_util: 0.5,
                cpu_util: 0.0,
                metrics: obj([("requests", Value::from(4usize))]),
            },
            Response::Result { id: Some(9), outcome: outcome.clone() },
            Response::Result { id: None, outcome: outcome.clone() },
            Response::BatchResult { id: Some(2), outcomes: vec![outcome.clone(), outcome] },
            Response::SessionOpened {
                id: Some(10),
                session: 3,
                target: "cpu-quant".into(),
                ttl_ms: 30_000,
            },
            Response::StreamResult {
                id: Some(11),
                session: 3,
                steps: 2,
                classes: vec![1, 4],
                logits: vec![0.0, 1.0, -0.5, 0.25, 2.0, 0.125],
                wall_latency_us: 42.5,
                target: "cpu".into(),
            },
            Response::SessionClosed { id: None, session: 3, steps: 17 },
            Response::HelloOk { proto: PROTO_V3_BINARY },
            Response::Error {
                id: Some(5),
                code: ErrorCode::InvalidLoad,
                message: "utilization 7 outside [0, 1]".into(),
            },
            Response::Error {
                id: Some(6),
                code: ErrorCode::Overloaded,
                message: "overloaded: scheduler queue full".into(),
            },
        ];
        for resp in cases {
            assert_eq!(Response::from_value(&resp.to_value()).unwrap(), resp, "{resp:?}");
            let line = resp.to_value().to_json();
            let back = Response::from_value(&crate::json::parse(&line).unwrap()).unwrap();
            assert_eq!(back, resp, "{line}");
        }
    }

    #[test]
    fn serve_errors_map_to_typed_codes() {
        assert_eq!(serve_error_code(&ServeError::DeadlineExceeded), ErrorCode::Deadline);
        assert_eq!(serve_error_code(&ServeError::Overloaded), ErrorCode::Overloaded);
        assert_eq!(
            serve_error_code(&ServeError::EngineFailure("x".into())),
            ErrorCode::Engine
        );
        assert_eq!(ErrorCode::parse("overloaded"), Some(ErrorCode::Overloaded));
        assert_eq!(ErrorCode::Overloaded.as_str(), "overloaded");
        assert_eq!(
            serve_error_code(&ServeError::SessionNotFound(4)),
            ErrorCode::SessionNotFound
        );
        assert_eq!(serve_error_code(&ServeError::SessionExpired(4)), ErrorCode::SessionExpired);
        assert_eq!(ErrorCode::parse("session_not_found"), Some(ErrorCode::SessionNotFound));
        assert_eq!(ErrorCode::parse("session_expired"), Some(ErrorCode::SessionExpired));
        assert_eq!(
            serve_error_code(&ServeError::RetriesExhausted),
            ErrorCode::RetriesExhausted
        );
        assert_eq!(ErrorCode::RetriesExhausted.as_str(), "retries_exhausted");
        assert_eq!(ErrorCode::parse("retries_exhausted"), Some(ErrorCode::RetriesExhausted));
    }

    #[test]
    fn responses_carry_protocol_version() {
        for resp in [Response::Pong, Response::Bye] {
            assert_eq!(resp.to_value().get("v").as_usize(), Some(PROTOCOL_VERSION as usize));
        }
    }

    #[test]
    fn hello_negotiation() {
        let r = router();
        assert_eq!(
            handle_line(&r, r#"{"type":"hello","proto":3}"#),
            Response::HelloOk { proto: 3 }
        );
        assert_eq!(r.metrics.proto_v3_negotiated.load(Ordering::Relaxed), 1);
        assert_eq!(
            handle_line(&r, r#"{"type":"hello","proto":2}"#),
            Response::HelloOk { proto: 2 }
        );
        assert_eq!(r.metrics.proto_v3_negotiated.load(Ordering::Relaxed), 1);
        match handle_line(&r, r#"{"type":"hello","proto":9}"#) {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::UnsupportedVersion),
            other => panic!("expected error, got {other:?}"),
        }
        match handle_line(&r, r#"{"type":"hello"}"#) {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn async_handler_matches_blocking_for_sync_and_classify() {
        let r = router();
        let (tx, rx) = std::sync::mpsc::channel();
        // Sync op: done fires inline.
        let t = tx.clone();
        handle_request_async(&r, Request::Ping, Box::new(move |resp| t.send(resp).unwrap()));
        assert_eq!(rx.try_recv().unwrap(), Response::Pong);
        // Classify: done fires later, from a pool worker.
        let window: Vec<f32> = (0..30).map(|i| i as f32 / 10.0).collect();
        let t = tx.clone();
        handle_request_async(
            &r,
            Request::Classify {
                id: Some(42),
                window,
                target: None,
                precision: None,
                deadline_ms: None,
                allow_degraded: false,
            },
            Box::new(move |resp| t.send(resp).unwrap()),
        );
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Response::Result { id, outcome } => {
                assert_eq!(id, Some(42));
                assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
            }
            other => panic!("expected result, got {other:?}"),
        }
        // Bad window: immediate typed error, done still fires once.
        let t = tx.clone();
        handle_request_async(
            &r,
            Request::Classify {
                id: Some(1),
                window: vec![0.0; 3],
                target: None,
                precision: None,
                deadline_ms: None,
                allow_degraded: false,
            },
            Box::new(move |resp| t.send(resp).unwrap()),
        );
        match rx.try_recv().unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn async_batch_fans_in_ordered() {
        let r = router();
        let w: Vec<f32> = (0..30).map(|i| i as f32).collect();
        let (tx, rx) = std::sync::mpsc::channel();
        handle_request_async(
            &r,
            Request::ClassifyBatch { id: Some(5), windows: vec![w.clone(), w.clone(), w] },
            Box::new(move |resp| tx.send(resp).unwrap()),
        );
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Response::BatchResult { id, outcomes } => {
                assert_eq!(id, Some(5));
                assert_eq!(outcomes.len(), 3);
                assert!(outcomes.iter().all(|o| o.class == 1));
            }
            other => panic!("expected batch_result, got {other:?}"),
        }
    }

    #[test]
    fn async_stream_lifecycle() {
        let r = router();
        let opened = match handle_request(&r, Request::OpenSession { id: None, precision: None })
        {
            Response::SessionOpened { session, .. } => session,
            other => panic!("expected session_opened, got {other:?}"),
        };
        let (tx, rx) = std::sync::mpsc::channel();
        handle_request_async(
            &r,
            Request::ClassifyStream { id: Some(9), session: opened, frames: vec![0.1, 0.2, 0.3] },
            Box::new(move |resp| tx.send(resp).unwrap()),
        );
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Response::StreamResult { id, session, steps, classes, .. } => {
                assert_eq!(id, Some(9));
                assert_eq!(session, opened);
                assert_eq!(steps, 1);
                assert_eq!(classes.len(), 1);
            }
            other => panic!("expected stream_result, got {other:?}"),
        }
        // Unknown session: typed error through the async path too.
        let (tx, rx) = std::sync::mpsc::channel();
        handle_request_async(
            &r,
            Request::ClassifyStream { id: None, session: 999_999, frames: vec![0.1, 0.2, 0.3] },
            Box::new(move |resp| tx.send(resp).unwrap()),
        );
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::SessionNotFound),
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn ping_pong_and_quit() {
        let r = router();
        let pong = handle_line(&r, r#"{"type":"ping"}"#);
        assert_eq!(pong, Response::Pong);
        let bye = handle_line(&r, r#"{"type":"quit","v":2}"#);
        assert_eq!(bye, Response::Bye);
    }

    #[test]
    fn version_mismatch_is_a_typed_error() {
        let r = router();
        let resp = handle_line(&r, r#"{"type":"ping","v":1,"id":3}"#);
        match resp {
            Response::Error { id, code, .. } => {
                assert_eq!(code, ErrorCode::UnsupportedVersion);
                assert_eq!(id, Some(3), "errors echo the request id");
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn malformed_lines_are_errors_not_panics() {
        let r = router();
        for (bad, want) in [
            ("", ErrorCode::BadJson),
            ("not json", ErrorCode::BadJson),
            ("{}", ErrorCode::BadRequest),
            (r#"{"type":"nope"}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify"}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify","window":["a"]}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify","window":[1,2,3]}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify","window":[],"target":"npu"}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify","window":[],"precision":"fp16"}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify","window":[],"precision":7}"#, ErrorCode::BadRequest),
            (r#"{"type":"classify_batch","windows":[]}"#, ErrorCode::BadRequest),
        ] {
            match handle_line(&r, bad) {
                Response::Error { code, .. } => assert_eq!(code, want, "{bad}"),
                other => panic!("{bad}: expected error, got {other:?}"),
            }
        }
    }

    #[test]
    fn classify_round_trip_with_id() {
        let r = router();
        let line = format!(r#"{{"type":"classify","id":42,"window":{}}}"#, window_json(30));
        match handle_line(&r, &line) {
            Response::Result { id, outcome } => {
                assert_eq!(id, Some(42));
                assert_eq!(outcome.class, 1, "FixedEngine predicts class 1");
                assert!(outcome.sim_latency_us > 0.0);
                assert_eq!(outcome.target, "cpu");
            }
            other => panic!("expected result, got {other:?}"),
        }
    }

    #[test]
    fn classify_precision_int8_reaches_quant_engine() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let r = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(crate::simulator::Target::CpuSingle))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::new(crate::simulator::Target::CpuSingle)))
            .engine(Box::new(FixedEngine::new(crate::simulator::Target::CpuQuant)))
            .build()
            .unwrap();
        let line = format!(
            r#"{{"type":"classify","id":3,"window":{},"precision":"int8"}}"#,
            window_json(30)
        );
        match handle_line(&r, &line) {
            Response::Result { id, outcome } => {
                assert_eq!(id, Some(3));
                assert_eq!(outcome.target, "cpu-quant", "precision must reach the quant pool");
            }
            other => panic!("expected result, got {other:?}"),
        }
    }

    #[test]
    fn classify_batch_returns_one_outcome_per_window() {
        let r = router();
        let w = window_json(30);
        let line = format!(r#"{{"type":"classify_batch","id":5,"windows":[{w},{w},{w}]}}"#);
        match handle_line(&r, &line) {
            Response::BatchResult { id, outcomes } => {
                assert_eq!(id, Some(5));
                assert_eq!(outcomes.len(), 3);
                assert!(outcomes.iter().all(|o| o.class == 1));
            }
            other => panic!("expected batch_result, got {other:?}"),
        }
    }

    #[test]
    fn set_load_validates_range() {
        let r = router();
        // In-range: applied and echoed (with the request id).
        match handle_line(&r, r#"{"type":"set_load","id":8,"gpu":0.75,"cpu":0.2}"#) {
            Response::LoadSet { id, gpu, cpu } => {
                assert_eq!(id, Some(8));
                assert!((gpu - 0.75).abs() < 1e-9);
                assert!((cpu - 0.2).abs() < 1e-9);
            }
            other => panic!("expected load_set, got {other:?}"),
        }
        // Out of range: typed error carrying the id, nothing applied.
        match handle_line(&r, r#"{"type":"set_load","id":9,"gpu":7.0}"#) {
            Response::Error { id, code, message } => {
                assert_eq!(id, Some(9), "invalid_load must echo the request id");
                assert_eq!(code, ErrorCode::InvalidLoad);
                assert!(message.contains("outside"), "{message}");
            }
            other => panic!("expected invalid_load, got {other:?}"),
        }
        assert!((r.device.gpu_util() - 0.75).abs() < 1e-9, "rejected load must not apply");
        for bad in [r#"{"type":"set_load","cpu":-0.1}"#, r#"{"type":"set_load","gpu":1.0001}"#] {
            match handle_line(&r, bad) {
                Response::Error { code, .. } => assert_eq!(code, ErrorCode::InvalidLoad, "{bad}"),
                other => panic!("{bad}: expected error, got {other:?}"),
            }
        }
    }

    #[test]
    fn non_integer_ids_are_rejected_loudly() {
        // v2 types ids as non-negative integers; anything else is a
        // bad_request, never a silent drop of the id echo.
        let r = router();
        for bad in [
            r#"{"type":"classify","id":"req-17","window":[]}"#,
            r#"{"type":"set_load","id":-1,"gpu":0.5}"#,
            r#"{"type":"classify_batch","id":1.5,"windows":[[1]]}"#,
        ] {
            match handle_line(&r, bad) {
                Response::Error { code, message, .. } => {
                    assert_eq!(code, ErrorCode::BadRequest, "{bad}");
                    assert!(message.contains("id"), "{bad}: {message}");
                }
                other => panic!("{bad}: expected error, got {other:?}"),
            }
        }
    }

    #[test]
    fn set_load_then_stats_reflects() {
        let r = router();
        handle_request(&r, Request::SetLoad { id: None, gpu: Some(0.75), cpu: Some(0.2) });
        match handle_request(&r, Request::Stats) {
            Response::Stats { gpu_util, cpu_util, metrics } => {
                assert!((gpu_util - 0.75).abs() < 1e-9);
                assert!((cpu_util - 0.2).abs() < 1e-9);
                assert!(metrics.get("requests").as_usize().is_some());
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn deadline_zero_yields_deadline_error() {
        let r = router();
        let line =
            format!(r#"{{"type":"classify","id":1,"window":{},"deadline_ms":0}}"#, window_json(30));
        match handle_line(&r, &line) {
            Response::Error { id, code, .. } => {
                assert_eq!(code, ErrorCode::Deadline);
                assert_eq!(id, Some(1));
            }
            other => panic!("expected deadline error, got {other:?}"),
        }
    }

    #[test]
    fn session_lifecycle_over_the_protocol() {
        let r = router();
        // Open: pins to the only stream-capable pool (FixedEngine "cpu").
        let session = match handle_line(&r, r#"{"type":"open_session","id":1,"v":2}"#) {
            Response::SessionOpened { id, session, target, ttl_ms } => {
                assert_eq!(id, Some(1));
                assert_eq!(target, "cpu");
                assert!(ttl_ms > 0);
                session
            }
            other => panic!("expected session_opened, got {other:?}"),
        };
        // Stream two steps (input_dim = 3 -> 6 values).
        let line = format!(
            r#"{{"type":"classify_stream","id":2,"session":{session},"frames":[0.1,0.2,0.3,0.4,0.5,0.6]}}"#
        );
        match handle_line(&r, &line) {
            Response::StreamResult { id, session: s, steps, classes, logits, target, .. } => {
                assert_eq!(id, Some(2));
                assert_eq!(s, session);
                assert_eq!(steps, 2);
                assert_eq!(classes, vec![1, 1], "FixedEngine predicts class 1 per step");
                assert_eq!(logits.len(), 2 * 6);
                assert_eq!(target, "cpu");
            }
            other => panic!("expected stream_result, got {other:?}"),
        }
        // Close: echoes the steps consumed.
        let line = format!(r#"{{"type":"close_session","id":3,"session":{session}}}"#);
        match handle_line(&r, &line) {
            Response::SessionClosed { id, session: s, steps } => {
                assert_eq!(id, Some(3));
                assert_eq!(s, session);
                assert_eq!(steps, 2);
            }
            other => panic!("expected session_closed, got {other:?}"),
        }
        // Streaming into a closed session is the typed not-found error.
        let line = format!(
            r#"{{"type":"classify_stream","id":4,"session":{session},"frames":[0.1,0.2,0.3]}}"#
        );
        match handle_line(&r, &line) {
            Response::Error { id, code, .. } => {
                assert_eq!(id, Some(4));
                assert_eq!(code, ErrorCode::SessionNotFound);
            }
            other => panic!("expected session_not_found, got {other:?}"),
        }
    }

    #[test]
    fn stream_frame_validation_is_a_bad_request() {
        let r = router();
        let session = match handle_request(
            &r,
            Request::OpenSession { id: None, precision: None },
        ) {
            Response::SessionOpened { session, .. } => session,
            other => panic!("expected session_opened, got {other:?}"),
        };
        // Empty and non-multiple-of-input_dim chunks never reach the
        // scheduler.
        for frames in [vec![], vec![0.5, 0.5]] {
            match handle_request(
                &r,
                Request::ClassifyStream { id: Some(9), session, frames },
            ) {
                Response::Error { id, code, message } => {
                    assert_eq!(id, Some(9));
                    assert_eq!(code, ErrorCode::BadRequest);
                    assert!(message.contains("input_dim"), "{message}");
                }
                other => panic!("expected bad_request, got {other:?}"),
            }
        }
    }

    #[test]
    fn open_session_without_capable_engine_is_typed() {
        // No quant engine registered: int8 open fails loudly, not with a
        // dropped connection.
        let r = router();
        match handle_line(&r, r#"{"type":"open_session","id":5,"precision":"int8"}"#) {
            Response::Error { id, code, message } => {
                assert_eq!(id, Some(5));
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("quantized"), "{message}");
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn engine_failure_surfaces_as_engine_error() {
        let shape =
            ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 };
        let r = Router::builder()
            .shape(shape)
            .policy(OffloadPolicy::Static(crate::simulator::Target::Gpu(
                Factorization::Coarse,
            )))
            .max_wait(std::time::Duration::from_millis(1))
            .engine(Box::new(FixedEngine::failing(crate::simulator::Target::CpuSingle)))
            .build()
            .unwrap();
        let line = format!(r#"{{"type":"classify","window":{}}}"#, window_json(30));
        match handle_line(&r, &line) {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Engine),
            other => panic!("expected engine error, got {other:?}"),
        }
    }
}
