//! Wire protocol v3: length-prefixed binary frames (DESIGN.md §12).
//!
//! JSON (protocol v2, [`super::protocol`]) costs ~96µs to decode one
//! classify request — every float is text. At int8 kernel speeds that
//! is the serving bottleneck, so v3 moves the hot path to a fixed
//! little-endian frame format: a 16-byte header (magic, version,
//! opcode, flags, bounded payload length, request id) followed by an
//! opcode-specific payload in which `f32` tensors travel as raw LE
//! bytes. Decoding a window is a bounds check plus one `memcpy` — or
//! no copy at all through [`classify_window`], which hands back a
//! borrowed [`F32View`] aliasing the wire bytes on aligned
//! little-endian hosts. There are no i8 tensor payloads: int8 is a
//! server-side precision contract (DESIGN.md §10), so windows are
//! always f32 on the wire and only the `precision` tag differs.
//!
//! Every protocol-v2 op — classify, batch, session lifecycle, stats,
//! set_load, hello — has a binary encoding here, byte-exactly
//! round-tripped by the tests below. A connection starts in JSON and
//! upgrades by sending `hello {"proto":3}`
//! ([`super::protocol::PROTO_V3_BINARY`]); after the server's
//! `hello_ok` both directions switch to frames. Decoding is total:
//! malformed input yields a typed [`FrameError`], never a panic, and
//! the declared payload length is checked against [`MAX_PAYLOAD`]
//! before any allocation, so a hostile length prefix cannot balloon
//! memory.
//!
//! ```text
//! offset  size  field
//! 0       1     magic 0xA7
//! 1       1     frame version (3)
//! 2       1     opcode (requests 0x01..; responses 0x81.., error 0xFF)
//! 3       1     flags (bit 0: id field meaningful)
//! 4       4     payload length, u32 LE, <= MAX_PAYLOAD
//! 8       8     request id, u64 LE (0 unless flags bit 0)
//! 16      n     payload
//! ```

use std::fmt;

use crate::coordinator::{parse_target, target_label, Precision};
use crate::server::protocol::{ClassifyOutcome, ErrorCode, Request, Response};

/// First byte of every frame; a connection that has negotiated v3 and
/// then sends anything else is treated as corrupt and closed.
pub const MAGIC: u8 = 0xA7;

/// Frame format version carried in byte 1.
pub const FRAME_VERSION: u8 = 3;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 16;

/// Hard bound on the declared payload length. Large enough for a
/// 4096-window batch of the default shape (~19 MB would exceed it;
/// batches that big should be split), small enough that a hostile
/// length prefix cannot make the server buffer unbounded memory.
pub const MAX_PAYLOAD: u32 = 16 << 20;

const FLAG_HAS_ID: u8 = 0x01;

// Request opcodes.
const OP_PING: u8 = 0x01;
const OP_QUIT: u8 = 0x02;
const OP_STATS: u8 = 0x03;
const OP_SET_LOAD: u8 = 0x04;
const OP_CLASSIFY: u8 = 0x05;
const OP_CLASSIFY_BATCH: u8 = 0x06;
const OP_OPEN_SESSION: u8 = 0x07;
const OP_CLASSIFY_STREAM: u8 = 0x08;
const OP_CLOSE_SESSION: u8 = 0x09;
const OP_HELLO: u8 = 0x0A;

// Response opcodes (high bit set).
const OP_PONG: u8 = 0x81;
const OP_BYE: u8 = 0x82;
const OP_STATS_R: u8 = 0x83;
const OP_LOAD_SET: u8 = 0x84;
const OP_RESULT: u8 = 0x85;
const OP_BATCH_RESULT: u8 = 0x86;
const OP_SESSION_OPENED: u8 = 0x87;
const OP_STREAM_RESULT: u8 = 0x88;
const OP_SESSION_CLOSED: u8 = 0x89;
const OP_HELLO_OK: u8 = 0x8A;
const OP_ERROR: u8 = 0xFF;

/// Typed decode failure. Decoding is total — every input maps to a
/// value or one of these, never a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// More bytes are needed before the frame can be judged. Streaming
    /// decoders treat this as "wait for more input"; one-shot decoders
    /// as corruption.
    Truncated,
    /// Byte 0 was not [`MAGIC`]; framing is lost, close the connection.
    BadMagic(u8),
    /// Byte 1 declared a frame version we do not speak.
    BadVersion(u8),
    /// Unknown opcode for the direction being decoded.
    BadOpcode(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
    /// Header was fine but the payload is structurally invalid for its
    /// opcode; framing is intact, so the connection can answer a typed
    /// error and continue.
    Malformed(&'static str),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated => write!(f, "incomplete frame"),
            FrameError::BadMagic(b) => write!(f, "bad frame magic {b:#04x}"),
            FrameError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            FrameError::BadOpcode(op) => write!(f, "unknown opcode {op:#04x}"),
            FrameError::Oversized(n) => {
                write!(f, "declared payload of {n} bytes exceeds the {MAX_PAYLOAD}-byte bound")
            }
            FrameError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// A parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub opcode: u8,
    pub flags: u8,
    pub payload_len: u32,
    /// Raw id field; meaningful only when flags bit 0 is set — use
    /// [`Header::id`].
    pub id_raw: u64,
}

impl Header {
    /// The request id, if the sender marked one.
    pub fn id(&self) -> Option<u64> {
        if self.flags & FLAG_HAS_ID != 0 {
            Some(self.id_raw)
        } else {
            None
        }
    }

    /// Total frame size: header plus declared payload.
    pub fn frame_len(&self) -> usize {
        HEADER_LEN + self.payload_len as usize
    }
}

/// Parse the fixed 16-byte header. Magic, version and the payload-length
/// bound are all enforced here, before any payload is buffered.
pub fn parse_header(bytes: &[u8]) -> Result<Header, FrameError> {
    if bytes.len() < HEADER_LEN {
        return Err(FrameError::Truncated);
    }
    if bytes[0] != MAGIC {
        return Err(FrameError::BadMagic(bytes[0]));
    }
    if bytes[1] != FRAME_VERSION {
        return Err(FrameError::BadVersion(bytes[1]));
    }
    let payload_len = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if payload_len > MAX_PAYLOAD {
        return Err(FrameError::Oversized(payload_len));
    }
    let mut id = [0u8; 8];
    id.copy_from_slice(&bytes[8..16]);
    Ok(Header {
        opcode: bytes[2],
        flags: bytes[3],
        payload_len,
        id_raw: u64::from_le_bytes(id),
    })
}

/// Incremental framing for the event loop's read buffer: `Ok(Some(n))`
/// when the buffer's first frame is `n` bytes long (it may not all be
/// buffered yet), `Ok(None)` when more header bytes are needed, and
/// `Err` when the prefix can never become a valid frame (bad magic /
/// version / oversized length — close the connection).
pub fn frame_len(buf: &[u8]) -> Result<Option<usize>, FrameError> {
    if !buf.is_empty() && buf[0] != MAGIC {
        return Err(FrameError::BadMagic(buf[0]));
    }
    if buf.len() >= 2 && buf[1] != FRAME_VERSION {
        return Err(FrameError::BadVersion(buf[1]));
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    parse_header(buf).map(|h| Some(h.frame_len()))
}

// ---- zero-copy tensor views ------------------------------------------

/// View over a raw little-endian `f32` payload. On little-endian hosts
/// where the wire bytes happen to be 4-aligned, `Borrowed` aliases them
/// directly — no copy, no per-element parse; otherwise values are
/// materialized on access from the raw bytes.
#[derive(Debug, Clone, Copy)]
pub enum F32View<'a> {
    Borrowed(&'a [f32]),
    Raw(&'a [u8]),
}

impl F32View<'_> {
    pub fn len(&self) -> usize {
        match self {
            F32View::Borrowed(s) => s.len(),
            F32View::Raw(b) => b.len() / 4,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `Some` only on the zero-copy path.
    pub fn as_borrowed(&self) -> Option<&[f32]> {
        match self {
            F32View::Borrowed(s) => Some(s),
            F32View::Raw(_) => None,
        }
    }

    /// Materialize an owned vector (one memcpy on the borrowed path).
    pub fn to_vec(&self) -> Vec<f32> {
        match self {
            F32View::Borrowed(s) => s.to_vec(),
            F32View::Raw(b) => b
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        }
    }
}

/// Build the cheapest possible view over raw LE f32 bytes.
fn f32_view(bytes: &[u8]) -> F32View<'_> {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: every bit pattern is a valid f32, and `align_to`
        // guarantees `mid` is correctly aligned; the borrow keeps the
        // backing bytes alive.
        let (prefix, mid, suffix) = unsafe { bytes.align_to::<f32>() };
        if prefix.is_empty() && suffix.is_empty() {
            return F32View::Borrowed(mid);
        }
    }
    F32View::Raw(bytes)
}

// ---- payload writer --------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

/// u16 length + UTF-8 bytes; anything past 64 KiB is truncated on a
/// char boundary (only error messages could ever get near that).
fn put_str(b: &mut Vec<u8>, s: &str) {
    let mut end = s.len().min(u16::MAX as usize);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u16(b, end as u16);
    b.extend_from_slice(&s.as_bytes()[..end]);
}

/// u32 length + raw bytes (embedded metrics JSON — not a hot path).
fn put_bytes32(b: &mut Vec<u8>, bytes: &[u8]) {
    put_u32(b, bytes.len() as u32);
    b.extend_from_slice(bytes);
}

/// u32 element count + raw LE f32 bytes.
fn put_f32s(b: &mut Vec<u8>, vals: &[f32]) {
    put_u32(b, vals.len() as u32);
    b.reserve(vals.len() * 4);
    for v in vals {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_opt_f64(b: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(v) => {
            put_u8(b, 1);
            put_f64(b, v);
        }
        None => put_u8(b, 0),
    }
}

fn put_opt_u64(b: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(v) => {
            put_u8(b, 1);
            put_u64(b, v);
        }
        None => put_u8(b, 0),
    }
}

fn put_opt_str(b: &mut Vec<u8>, v: Option<&str>) {
    match v {
        Some(s) => {
            put_u8(b, 1);
            put_str(b, s);
        }
        None => put_u8(b, 0),
    }
}

/// Stamp the header over the first [`HEADER_LEN`] bytes (reserved as
/// zeros by the encoders) once the payload length is known.
fn finish_frame(mut buf: Vec<u8>, opcode: u8, id: Option<u64>) -> Vec<u8> {
    let payload_len = (buf.len() - HEADER_LEN) as u32;
    buf[0] = MAGIC;
    buf[1] = FRAME_VERSION;
    buf[2] = opcode;
    buf[3] = if id.is_some() { FLAG_HAS_ID } else { 0 };
    buf[4..8].copy_from_slice(&payload_len.to_le_bytes());
    buf[8..16].copy_from_slice(&id.unwrap_or(0).to_le_bytes());
    buf
}

// ---- payload cursor --------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bounds-checked slice take: length is validated against what is
    /// actually buffered BEFORE anything is allocated, so hostile
    /// counts cannot balloon memory.
    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(FrameError::Malformed("length overflow"))?;
        if end > self.buf.len() {
            return Err(FrameError::Malformed("payload truncated"));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64, FrameError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, FrameError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| FrameError::Malformed("string is not utf-8"))
    }

    fn bytes32(&mut self) -> Result<&'a [u8], FrameError> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    fn f32s(&mut self) -> Result<F32View<'a>, FrameError> {
        let n = self.u32()? as usize;
        let byte_len = n
            .checked_mul(4)
            .ok_or(FrameError::Malformed("f32 count overflow"))?;
        Ok(f32_view(self.take(byte_len)?))
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, FrameError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            _ => Err(FrameError::Malformed("bad presence byte")),
        }
    }

    fn opt_u64(&mut self) -> Result<Option<u64>, FrameError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            _ => Err(FrameError::Malformed("bad presence byte")),
        }
    }

    fn opt_str(&mut self) -> Result<Option<String>, FrameError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str()?)),
            _ => Err(FrameError::Malformed("bad presence byte")),
        }
    }

    /// Every decoder ends with this: leftover bytes mean the sender and
    /// receiver disagree about the payload layout.
    fn finish(self) -> Result<(), FrameError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(FrameError::Malformed("trailing payload bytes"))
        }
    }
}

// ---- request codec ---------------------------------------------------

/// Encode a request into one complete frame.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut b = vec![0u8; HEADER_LEN];
    let (opcode, id) = match req {
        Request::Ping => (OP_PING, None),
        Request::Quit => (OP_QUIT, None),
        Request::Stats => (OP_STATS, None),
        Request::SetLoad { id, gpu, cpu } => {
            put_opt_f64(&mut b, *gpu);
            put_opt_f64(&mut b, *cpu);
            (OP_SET_LOAD, *id)
        }
        Request::Classify { id, window, target, precision, deadline_ms, allow_degraded } => {
            put_f32s(&mut b, window);
            put_opt_str(&mut b, target.map(target_label));
            put_opt_str(&mut b, precision.map(Precision::as_str));
            put_opt_u64(&mut b, *deadline_ms);
            put_u8(&mut b, *allow_degraded as u8);
            (OP_CLASSIFY, *id)
        }
        Request::ClassifyBatch { id, windows } => {
            put_u32(&mut b, windows.len() as u32);
            for w in windows {
                put_f32s(&mut b, w);
            }
            (OP_CLASSIFY_BATCH, *id)
        }
        Request::OpenSession { id, precision } => {
            put_opt_str(&mut b, precision.map(Precision::as_str));
            (OP_OPEN_SESSION, *id)
        }
        Request::ClassifyStream { id, session, frames } => {
            put_u64(&mut b, *session);
            put_f32s(&mut b, frames);
            (OP_CLASSIFY_STREAM, *id)
        }
        Request::CloseSession { id, session } => {
            put_u64(&mut b, *session);
            (OP_CLOSE_SESSION, *id)
        }
        Request::Hello { proto } => {
            put_u64(&mut b, *proto);
            (OP_HELLO, None)
        }
    };
    finish_frame(b, opcode, id)
}

/// Decode one complete request frame (header + exactly its payload).
pub fn decode_request(frame: &[u8]) -> Result<Request, FrameError> {
    let h = parse_header(frame)?;
    decode_request_body(&h, payload(&h, frame)?)
}

/// Decode a request from an already-parsed header and its payload —
/// the form the transports use after reading the two pieces off a
/// socket separately.
pub fn decode_request_body(h: &Header, payload: &[u8]) -> Result<Request, FrameError> {
    if payload.len() != h.payload_len as usize {
        return Err(FrameError::Truncated);
    }
    let mut c = Cursor::new(payload);
    let id = h.id();
    let req = match h.opcode {
        OP_PING => Request::Ping,
        OP_QUIT => Request::Quit,
        OP_STATS => Request::Stats,
        OP_SET_LOAD => Request::SetLoad { id, gpu: c.opt_f64()?, cpu: c.opt_f64()? },
        OP_CLASSIFY => {
            let window = c.f32s()?.to_vec();
            let target = match c.opt_str()? {
                None => None,
                Some(label) => Some(
                    parse_target(&label).ok_or(FrameError::Malformed("unknown target"))?,
                ),
            };
            let precision = match c.opt_str()? {
                None => None,
                Some(label) => Some(
                    Precision::parse(&label)
                        .ok_or(FrameError::Malformed("unknown precision"))?,
                ),
            };
            let deadline_ms = c.opt_u64()?;
            let allow_degraded = match c.u8()? {
                0 => false,
                1 => true,
                _ => return Err(FrameError::Malformed("bad allow_degraded byte")),
            };
            Request::Classify { id, window, target, precision, deadline_ms, allow_degraded }
        }
        OP_CLASSIFY_BATCH => {
            let n = c.u32()? as usize;
            let mut windows = Vec::new();
            for _ in 0..n {
                windows.push(c.f32s()?.to_vec());
            }
            Request::ClassifyBatch { id, windows }
        }
        OP_OPEN_SESSION => {
            let precision = match c.opt_str()? {
                None => None,
                Some(label) => Some(
                    Precision::parse(&label)
                        .ok_or(FrameError::Malformed("unknown precision"))?,
                ),
            };
            Request::OpenSession { id, precision }
        }
        OP_CLASSIFY_STREAM => Request::ClassifyStream {
            id,
            session: c.u64()?,
            frames: c.f32s()?.to_vec(),
        },
        OP_CLOSE_SESSION => Request::CloseSession { id, session: c.u64()? },
        OP_HELLO => Request::Hello { proto: c.u64()? },
        other => return Err(FrameError::BadOpcode(other)),
    };
    c.finish()?;
    Ok(req)
}

/// Zero-copy fast path: borrow a classify frame's window directly from
/// the wire bytes without building a [`Request`]. This is the decode
/// cost the v3 design is about — a header check and a slice borrow
/// instead of parsing thousands of text floats.
pub fn classify_window(frame: &[u8]) -> Result<F32View<'_>, FrameError> {
    let h = parse_header(frame)?;
    if h.opcode != OP_CLASSIFY {
        return Err(FrameError::BadOpcode(h.opcode));
    }
    let mut c = Cursor::new(payload(&h, frame)?);
    c.f32s()
}

/// The payload slice of a complete frame.
fn payload<'a>(h: &Header, frame: &'a [u8]) -> Result<&'a [u8], FrameError> {
    let end = h.frame_len();
    if frame.len() < end {
        return Err(FrameError::Truncated);
    }
    if frame.len() > end {
        return Err(FrameError::Malformed("trailing bytes after frame"));
    }
    Ok(&frame[HEADER_LEN..end])
}

// ---- response codec --------------------------------------------------

fn put_outcome(b: &mut Vec<u8>, o: &ClassifyOutcome) {
    put_u32(b, o.class as u32);
    put_str(b, &o.label);
    put_f64(b, o.sim_latency_us);
    put_f64(b, o.wall_latency_us);
    put_str(b, &o.target);
    put_u32(b, o.batch_size as u32);
    put_opt_str(b, o.degraded.as_deref());
}

fn get_outcome(c: &mut Cursor<'_>) -> Result<ClassifyOutcome, FrameError> {
    Ok(ClassifyOutcome {
        class: c.u32()? as usize,
        label: c.str()?,
        sim_latency_us: c.f64()?,
        wall_latency_us: c.f64()?,
        target: c.str()?,
        batch_size: c.u32()? as usize,
        degraded: c.opt_str()?,
    })
}

/// Encode a response into one complete frame.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut b = vec![0u8; HEADER_LEN];
    let (opcode, id) = match resp {
        Response::Pong => (OP_PONG, None),
        Response::Bye => (OP_BYE, None),
        Response::Stats { gpu_util, cpu_util, metrics } => {
            put_f64(&mut b, *gpu_util);
            put_f64(&mut b, *cpu_util);
            put_bytes32(&mut b, metrics.to_json().as_bytes());
            (OP_STATS_R, None)
        }
        Response::LoadSet { id, gpu, cpu } => {
            put_f64(&mut b, *gpu);
            put_f64(&mut b, *cpu);
            (OP_LOAD_SET, *id)
        }
        Response::Result { id, outcome } => {
            put_outcome(&mut b, outcome);
            (OP_RESULT, *id)
        }
        Response::BatchResult { id, outcomes } => {
            put_u32(&mut b, outcomes.len() as u32);
            for o in outcomes {
                put_outcome(&mut b, o);
            }
            (OP_BATCH_RESULT, *id)
        }
        Response::SessionOpened { id, session, target, ttl_ms } => {
            put_u64(&mut b, *session);
            put_str(&mut b, target);
            put_u64(&mut b, *ttl_ms);
            (OP_SESSION_OPENED, *id)
        }
        Response::StreamResult {
            id,
            session,
            steps,
            classes,
            logits,
            wall_latency_us,
            target,
        } => {
            put_u64(&mut b, *session);
            put_u32(&mut b, *steps as u32);
            put_u32(&mut b, classes.len() as u32);
            for cl in classes {
                put_u32(&mut b, *cl as u32);
            }
            put_f32s(&mut b, logits);
            put_f64(&mut b, *wall_latency_us);
            put_str(&mut b, target);
            (OP_STREAM_RESULT, *id)
        }
        Response::SessionClosed { id, session, steps } => {
            put_u64(&mut b, *session);
            put_u64(&mut b, *steps);
            (OP_SESSION_CLOSED, *id)
        }
        Response::HelloOk { proto } => {
            put_u64(&mut b, *proto);
            (OP_HELLO_OK, None)
        }
        Response::Error { id, code, message } => {
            put_str(&mut b, code.as_str());
            put_str(&mut b, message);
            (OP_ERROR, *id)
        }
    };
    finish_frame(b, opcode, id)
}

/// Decode one complete response frame.
pub fn decode_response(frame: &[u8]) -> Result<Response, FrameError> {
    let h = parse_header(frame)?;
    decode_response_body(&h, payload(&h, frame)?)
}

/// Decode a response from an already-parsed header and its payload.
pub fn decode_response_body(h: &Header, payload: &[u8]) -> Result<Response, FrameError> {
    if payload.len() != h.payload_len as usize {
        return Err(FrameError::Truncated);
    }
    let mut c = Cursor::new(payload);
    let id = h.id();
    let resp = match h.opcode {
        OP_PONG => Response::Pong,
        OP_BYE => Response::Bye,
        OP_STATS_R => {
            let gpu_util = c.f64()?;
            let cpu_util = c.f64()?;
            let bytes = c.bytes32()?;
            let text = std::str::from_utf8(bytes)
                .map_err(|_| FrameError::Malformed("metrics json is not utf-8"))?;
            let metrics = crate::json::parse(text)
                .map_err(|_| FrameError::Malformed("bad metrics json"))?;
            if metrics.as_obj().is_none() {
                return Err(FrameError::Malformed("metrics is not an object"));
            }
            Response::Stats { gpu_util, cpu_util, metrics }
        }
        OP_LOAD_SET => Response::LoadSet { id, gpu: c.f64()?, cpu: c.f64()? },
        OP_RESULT => Response::Result { id, outcome: get_outcome(&mut c)? },
        OP_BATCH_RESULT => {
            let n = c.u32()? as usize;
            let mut outcomes = Vec::new();
            for _ in 0..n {
                outcomes.push(get_outcome(&mut c)?);
            }
            Response::BatchResult { id, outcomes }
        }
        OP_SESSION_OPENED => Response::SessionOpened {
            id,
            session: c.u64()?,
            target: c.str()?,
            ttl_ms: c.u64()?,
        },
        OP_STREAM_RESULT => {
            let session = c.u64()?;
            let steps = c.u32()? as usize;
            let n = c.u32()? as usize;
            let mut classes = Vec::new();
            for _ in 0..n {
                classes.push(c.u32()? as usize);
            }
            let logits = c.f32s()?.to_vec();
            let wall_latency_us = c.f64()?;
            let target = c.str()?;
            Response::StreamResult { id, session, steps, classes, logits, wall_latency_us, target }
        }
        OP_SESSION_CLOSED => Response::SessionClosed { id, session: c.u64()?, steps: c.u64()? },
        OP_HELLO_OK => Response::HelloOk { proto: c.u64()? },
        OP_ERROR => {
            let code_str = c.str()?;
            let code = ErrorCode::parse(&code_str)
                .ok_or(FrameError::Malformed("unknown error code"))?;
            Response::Error { id, code, message: c.str()? }
        }
        other => return Err(FrameError::BadOpcode(other)),
    };
    c.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{obj, Value};
    use crate::simulator::Target;

    fn request_cases() -> Vec<Request> {
        vec![
            Request::Ping,
            Request::Quit,
            Request::Stats,
            Request::SetLoad { id: Some(11), gpu: Some(0.5), cpu: None },
            Request::SetLoad { id: None, gpu: None, cpu: Some(1.0) },
            Request::Classify {
                id: Some(7),
                window: vec![0.25, -1.5, 0.0],
                target: Some(Target::CpuMulti(4)),
                precision: None,
                deadline_ms: Some(250),
                allow_degraded: false,
            },
            Request::Classify {
                id: Some(8),
                window: vec![1.0],
                target: None,
                precision: Some(Precision::Int8),
                deadline_ms: None,
                allow_degraded: false,
            },
            Request::Classify {
                id: None,
                window: vec![],
                target: None,
                precision: None,
                deadline_ms: None,
                allow_degraded: true,
            },
            Request::ClassifyBatch { id: Some(1), windows: vec![vec![1.0, 2.0], vec![3.0, 4.0]] },
            Request::ClassifyBatch { id: None, windows: vec![] },
            Request::OpenSession { id: Some(12), precision: None },
            Request::OpenSession { id: None, precision: Some(Precision::Int8) },
            Request::ClassifyStream { id: Some(13), session: 7, frames: vec![0.5, -0.25, 1.0] },
            Request::CloseSession { id: None, session: u64::MAX },
            Request::Hello { proto: 3 },
        ]
    }

    fn response_cases() -> Vec<Response> {
        let outcome = ClassifyOutcome {
            class: 3,
            label: "sitting".into(),
            sim_latency_us: 1234.5,
            wall_latency_us: 88.25,
            target: "gpu".into(),
            batch_size: 4,
            degraded: None,
        };
        vec![
            Response::Pong,
            Response::Bye,
            Response::LoadSet { id: Some(4), gpu: 0.75, cpu: 0.25 },
            Response::Stats {
                gpu_util: 0.5,
                cpu_util: 0.0,
                metrics: obj([("requests", Value::from(4usize))]),
            },
            Response::Result { id: Some(9), outcome: outcome.clone() },
            Response::Result { id: None, outcome: outcome.clone() },
            Response::BatchResult { id: Some(2), outcomes: vec![outcome.clone(), outcome] },
            Response::BatchResult { id: None, outcomes: vec![] },
            Response::SessionOpened {
                id: Some(10),
                session: 3,
                target: "cpu-quant".into(),
                ttl_ms: 30_000,
            },
            Response::StreamResult {
                id: Some(11),
                session: 3,
                steps: 2,
                classes: vec![1, 4],
                logits: vec![0.0, 1.0, -0.5, 0.25, 2.0, 0.125],
                wall_latency_us: 42.5,
                target: "cpu".into(),
            },
            Response::SessionClosed { id: None, session: 3, steps: 17 },
            Response::HelloOk { proto: 3 },
            Response::Error {
                id: Some(5),
                code: ErrorCode::Overloaded,
                message: "overloaded: scheduler queue full".into(),
            },
            Response::Error { id: None, code: ErrorCode::BadRequest, message: String::new() },
        ]
    }

    #[test]
    fn header_layout_is_byte_exact() {
        let frame = encode_request(&Request::Ping);
        assert_eq!(frame.len(), HEADER_LEN, "ping has an empty payload");
        assert_eq!(frame[0], 0xA7);
        assert_eq!(frame[1], 3);
        assert_eq!(frame[2], 0x01);
        assert_eq!(frame[3], 0, "ping carries no id");
        assert_eq!(&frame[4..16], &[0u8; 12][..], "zero payload length and id");

        let frame = encode_request(&Request::CloseSession { id: Some(0x0102), session: 9 });
        assert_eq!(frame[2], 0x09);
        assert_eq!(frame[3], 1, "id flag set");
        assert_eq!(u32::from_le_bytes(frame[4..8].try_into().unwrap()), 8);
        assert_eq!(u64::from_le_bytes(frame[8..16].try_into().unwrap()), 0x0102);
        assert_eq!(u64::from_le_bytes(frame[16..24].try_into().unwrap()), 9);
    }

    #[test]
    fn every_request_round_trips_byte_exact() {
        for req in request_cases() {
            let frame = encode_request(&req);
            let back = decode_request(&frame).unwrap();
            assert_eq!(back, req, "decode(encode(x)) != x");
            // Byte-exact: re-encoding the decoded value reproduces the
            // identical frame.
            assert_eq!(encode_request(&back), frame, "{req:?}");
        }
    }

    #[test]
    fn every_response_round_trips_byte_exact() {
        for resp in response_cases() {
            let frame = encode_response(&resp);
            let back = decode_response(&frame).unwrap();
            assert_eq!(back, resp, "decode(encode(x)) != x");
            assert_eq!(encode_response(&back), frame, "{resp:?}");
        }
    }

    #[test]
    fn truncated_frames_are_typed_errors() {
        let frame = encode_request(&Request::Classify {
            id: Some(1),
            window: vec![1.0, 2.0, 3.0],
            target: None,
            precision: None,
            deadline_ms: None,
            allow_degraded: false,
        });
        for k in 0..frame.len() {
            let err = decode_request(&frame[..k]).unwrap_err();
            assert!(
                matches!(err, FrameError::Truncated | FrameError::Malformed(_)),
                "prefix of {k} bytes: unexpected {err:?}"
            );
        }
        // Streaming view: a partial header is "wait", a full header
        // names the final length even before the payload arrives.
        assert_eq!(frame_len(&frame[..4]), Ok(None));
        assert_eq!(frame_len(&frame[..HEADER_LEN]), Ok(Some(frame.len())));
        assert_eq!(frame_len(&frame), Ok(Some(frame.len())));
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let mut frame = encode_request(&Request::Ping);
        frame[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(parse_header(&frame), Err(FrameError::Oversized(MAX_PAYLOAD + 1)));
        assert_eq!(frame_len(&frame), Err(FrameError::Oversized(MAX_PAYLOAD + 1)));
        // A length that lies WITHIN the bound but past the actual
        // payload is caught by the cursor, not by allocation.
        let mut frame = encode_request(&Request::Ping);
        frame[4..8].copy_from_slice(&1024u32.to_le_bytes());
        assert_eq!(decode_request(&frame), Err(FrameError::Truncated));
    }

    #[test]
    fn garbage_headers_are_typed_errors() {
        assert_eq!(frame_len(b"GET / HTTP/1.1"), Err(FrameError::BadMagic(b'G')));
        let mut frame = encode_request(&Request::Ping);
        frame[1] = 9;
        assert_eq!(decode_request(&frame), Err(FrameError::BadVersion(9)));
        let mut frame = encode_request(&Request::Ping);
        frame[2] = 0x55;
        assert_eq!(decode_request(&frame), Err(FrameError::BadOpcode(0x55)));
        // Response opcode on the request decoder and vice versa.
        let resp_frame = encode_response(&Response::Pong);
        assert_eq!(decode_request(&resp_frame), Err(FrameError::BadOpcode(OP_PONG)));
        let req_frame = encode_request(&Request::Ping);
        assert_eq!(decode_response(&req_frame), Err(FrameError::BadOpcode(OP_PING)));
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut frame = encode_request(&Request::Ping);
        frame.push(0);
        assert_eq!(
            decode_request(&frame),
            Err(FrameError::Malformed("trailing bytes after frame"))
        );
    }

    #[test]
    fn malformed_payloads_never_panic() {
        // Flip every byte of a valid classify frame through a few
        // values: decoding must always return Ok or a typed Err.
        let frame = encode_request(&Request::Classify {
            id: Some(3),
            window: vec![0.5; 8],
            target: Some(Target::CpuSingle),
            precision: Some(Precision::F32),
            deadline_ms: Some(9),
            allow_degraded: true,
        });
        for i in 0..frame.len() {
            for delta in [1u8, 0x7F, 0xFF] {
                let mut bad = frame.clone();
                bad[i] = bad[i].wrapping_add(delta);
                let _ = decode_request(&bad);
            }
        }
        // Unknown target / precision labels are Malformed, not panics.
        let frame = encode_request(&Request::Classify {
            id: None,
            window: vec![],
            target: Some(Target::CpuSingle),
            precision: None,
            deadline_ms: None,
            allow_degraded: false,
        });
        let text: &[u8] = b"cpu";
        // Corrupt the target label in place ("cpu" -> "cpx").
        let pos = frame.windows(text.len()).position(|w| w == text).unwrap();
        let mut bad = frame.clone();
        bad[pos + 2] = b'x';
        assert_eq!(decode_request(&bad), Err(FrameError::Malformed("unknown target")));
    }

    #[test]
    fn zero_copy_view_on_aligned_little_endian() {
        let window: Vec<f32> = (0..32).map(|i| i as f32 * 0.25 - 4.0).collect();
        let frame = encode_request(&Request::Classify {
            id: None,
            window: window.clone(),
            target: None,
            precision: None,
            deadline_ms: None,
            allow_degraded: false,
        });
        let view = classify_window(&frame).unwrap();
        assert_eq!(view.len(), window.len());
        assert_eq!(view.to_vec(), window);
        // The window payload starts at byte 20 (header 16 + count 4);
        // whenever the frame buffer is 4-aligned the view borrows.
        if cfg!(target_endian = "little") && frame.as_ptr() as usize % 4 == 0 {
            assert!(view.as_borrowed().is_some(), "aligned LE decode must not copy");
        }
        // Unaligned raw path computes the same values.
        let mut shifted = vec![0u8; 1];
        shifted.extend_from_slice(&frame[HEADER_LEN + 4..]);
        let raw = f32_view(&shifted[1..]);
        assert_eq!(raw.to_vec(), window);
    }

    #[test]
    fn error_strings_are_bounded() {
        let long = "x".repeat(100_000);
        let frame = encode_response(&Response::Error {
            id: None,
            code: ErrorCode::Engine,
            message: long,
        });
        match decode_response(&frame).unwrap() {
            Response::Error { message, .. } => {
                assert_eq!(message.len(), u16::MAX as usize, "truncated to the u16 bound");
            }
            other => panic!("expected error, got {other:?}"),
        }
    }
}
