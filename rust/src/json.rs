//! Minimal JSON parser + writer.
//!
//! The image's vendored crate set has no `serde`/`serde_json`, so the
//! manifest loader ([`crate::config`]) and the serving wire protocol
//! ([`crate::server`]) use this self-contained implementation: a
//! recursive-descent parser into a [`Value`] tree plus an escaping
//! writer. Supports the full JSON grammar (RFC 8259), including `\uXXXX`
//! escapes with UTF-16 surrogate pairs for astral-plane characters; lone
//! or mismatched surrogates are rejected as parse errors.
//!
//! On top of the tree sit the [`ToValue`]/[`FromValue`] codec traits:
//! typed messages (the protocol-v2 `Request`/`Response` enums in
//! [`crate::server::protocol`]) convert to and from `Value` through
//! them, so serialization and malformed-input handling live here, in one
//! tested place, rather than at every call site.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Error produced when decoding a [`Value`] into a typed message.
#[derive(Debug, Clone, PartialEq)]
pub struct CodecError {
    pub msg: String,
}

impl CodecError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Convenience for "field X: problem" errors.
    pub fn field(name: &str, problem: impl fmt::Display) -> Self {
        Self { msg: format!("field {name:?}: {problem}") }
    }
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for CodecError {}

/// Types that serialize themselves into a JSON [`Value`].
///
/// Together with [`FromValue`] this is the codec seam the serving wire
/// protocol is built on (DESIGN.md §7): every message the server reads or
/// writes is a typed struct/enum implementing both traits, so field
/// names, ids and malformed-input handling live in one tested place
/// instead of being assembled ad hoc at each call site.
pub trait ToValue {
    fn to_value(&self) -> Value;
}

/// Types that parse themselves out of a JSON [`Value`].
pub trait FromValue: Sized {
    fn from_value(v: &Value) -> Result<Self, CodecError>;
}

impl ToValue for Value {
    fn to_value(&self) -> Value {
        self.clone()
    }
}

impl FromValue for Value {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        Ok(v.clone())
    }
}

impl ToValue for bool {
    fn to_value(&self) -> Value {
        Value::Bool(*self)
    }
}

impl FromValue for bool {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_bool().ok_or_else(|| CodecError::new("expected bool"))
    }
}

impl ToValue for f64 {
    fn to_value(&self) -> Value {
        Value::Num(*self)
    }
}

impl FromValue for f64 {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_f64().ok_or_else(|| CodecError::new("expected number"))
    }
}

impl ToValue for f32 {
    fn to_value(&self) -> Value {
        Value::Num(*self as f64)
    }
}

impl FromValue for f32 {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_f64().map(|n| n as f32).ok_or_else(|| CodecError::new("expected number"))
    }
}

impl ToValue for usize {
    fn to_value(&self) -> Value {
        Value::Num(*self as f64)
    }
}

impl FromValue for usize {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_usize().ok_or_else(|| CodecError::new("expected non-negative integer"))
    }
}

impl ToValue for u64 {
    fn to_value(&self) -> Value {
        Value::Num(*self as f64)
    }
}

impl FromValue for u64 {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_usize().map(|u| u as u64).ok_or_else(|| CodecError::new("expected non-negative integer"))
    }
}

impl ToValue for String {
    fn to_value(&self) -> Value {
        Value::Str(self.clone())
    }
}

impl FromValue for String {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_str().map(str::to_string).ok_or_else(|| CodecError::new("expected string"))
    }
}

impl<T: ToValue> ToValue for Vec<T> {
    fn to_value(&self) -> Value {
        Value::Arr(self.iter().map(ToValue::to_value).collect())
    }
}

impl<T: FromValue> FromValue for Vec<T> {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        v.as_arr()
            .ok_or_else(|| CodecError::new("expected array"))?
            .iter()
            .map(T::from_value)
            .collect()
    }
}

impl<T: ToValue> ToValue for Option<T> {
    fn to_value(&self) -> Value {
        match self {
            Some(t) => t.to_value(),
            None => Value::Null,
        }
    }
}

impl<T: FromValue> FromValue for Option<T> {
    fn from_value(v: &Value) -> Result<Self, CodecError> {
        match v {
            Value::Null => Ok(None),
            other => T::from_value(other).map(Some),
        }
    }
}

impl Value {
    // ---- accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Value {
        const NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` chained for required fields with a readable error.
    pub fn req(&self, key: &str) -> Result<&Value, String> {
        match self {
            Value::Obj(o) => o.get(key).ok_or_else(|| format!("missing field {key:?}")),
            _ => Err(format!("expected object while reading {key:?}")),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    // ---- writer ----------------------------------------------------

    /// Serialize compactly (no whitespace).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                use std::fmt::Write;
                if !n.is_finite() {
                    // JSON has no Inf/NaN; null is the least-surprising
                    // lowering (mirrors serde_json's arbitrary-precision
                    // behaviour). The parser refuses to produce them.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if (*n as f32) as f64 == *n {
                    // Exactly representable as f32 (the common case: our
                    // sensor data is f32 upcast for the wire). Rust's
                    // shortest-roundtrip float formatting then emits
                    // "0.55" instead of "0.550000011920929" — ~3× fewer
                    // bytes and ~2× faster serialization (§Perf).
                    let _ = write!(out, "{}", *n as f32);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- convenience constructors ---------------------------------------

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object value: `obj([("k", v.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Value)>>(fields: I) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---- parser ----------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    /// Read exactly four hex digits of a `\u` escape.
    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            code = code * 16 + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
        }
        Ok(code)
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        let c = match code {
                            // High surrogate: RFC 8259 requires an
                            // immediately following low surrogate escape;
                            // the pair combines into one astral scalar.
                            0xD800..=0xDBFF => {
                                if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                    return Err(self.err("unpaired high surrogate"));
                                }
                                let low = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&low) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let scalar = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                char::from_u32(scalar)
                                    .ok_or_else(|| self.err("bad surrogate pair"))?
                            }
                            0xDC00..=0xDFFF => {
                                return Err(self.err("unpaired low surrogate"));
                            }
                            // Any other BMP code point is a valid scalar.
                            _ => char::from_u32(code).ok_or_else(|| self.err("bad \\u escape"))?,
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at b.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text.parse().map_err(|_| self.err("bad number"))?;
        // "1e999" parses to +inf in Rust; JSON numbers must stay finite.
        if !n.is_finite() {
            return Err(self.err("number overflows f64"));
        }
        Ok(Value::Num(n))
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("d"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_escapes() {
        // BMP escapes.
        assert_eq!(parse(r#""A\u00e9\u4e16""#).unwrap().as_str(), Some("A\u{e9}\u{4e16}"));
        // Surrogate pair combines into one astral scalar (U+1F600).
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap().as_str(), Some("\u{1f600}"));
        assert_eq!(parse(r#""x\ud83d\ude00y""#).unwrap().as_str(), Some("x\u{1f600}y"));
        // Case-insensitive hex digits.
        assert_eq!(parse(r#""\uD83D\uDE00""#).unwrap().as_str(), Some("\u{1f600}"));
        // Escaped and literal forms agree.
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), parse("\"\u{1f600}\"").unwrap());
    }

    #[test]
    fn rejects_lone_surrogates() {
        // High surrogate with no continuation, wrong continuation, or a
        // non-surrogate follower; low surrogate on its own.
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\ud83dx""#).is_err());
        assert!(parse(r#""\ud83d\n""#).is_err());
        assert!(parse(r#""\ud83dA""#).is_err());
        assert!(parse(r#""\ude00""#).is_err());
        assert!(parse(r#""\ud83d\ud83d""#).is_err());
        // Truncated hex.
        assert!(parse(r#""\ud8""#).is_err());
        assert!(parse(r#""\uzzzz""#).is_err());
    }

    #[test]
    fn astral_roundtrip() {
        // Astral chars the writer emits raw must survive write→parse.
        for s in ["😀", "emoji 🎉 mix 𐍈", "\u{10348}\u{1f600}"] {
            let v = Value::Str(s.to_string());
            let rt = parse(&v.to_json()).unwrap();
            assert_eq!(rt.as_str(), Some(s), "astral round-trip broke {s:?}");
        }
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nulll").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true}"#,
            r#"[1.5,-2,0]"#,
            r#""quote\" and backslash\\""#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let re = parse(&v.to_json()).unwrap();
            assert_eq!(v, re, "{c}");
        }
    }

    #[test]
    fn writer_escapes_control() {
        let v = Value::Str("a\u{1}b".into());
        assert_eq!(v.to_json(), "\"a\\u0001b\"");
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Value::Num(5.0).to_json(), "5");
        assert_eq!(Value::Num(5.5).to_json(), "5.5");
    }

    #[test]
    fn f32_values_write_short_and_roundtrip_at_f32() {
        // Contract: values exactly representable as f32 are emitted in
        // f32-shortest form. Round-tripping preserves the f32 value
        // (what the serving wire carries); f64s that are NOT f32-exact
        // keep full f64 round-tripping.
        let v = Value::Num(0.55f32 as f64);
        assert_eq!(v.to_json(), "0.55");
        let back = parse(&v.to_json()).unwrap().as_f64().unwrap();
        assert_eq!(back as f32, 0.55f32);

        let precise = Value::Num(0.1f64 + 0.2f64); // not f32-exact
        let back = parse(&precise.to_json()).unwrap();
        assert_eq!(back, precise);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "b": false, "a": []}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("n").as_f64(), Some(3.0));
        assert_eq!(v.get("b").as_bool(), Some(false));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 0);
        assert!(v.get("missing").is_null());
        assert!(v.req("missing").is_err());
        assert!(v.req("n").is_ok());
        assert_eq!(Value::Num(3.5).as_usize(), None);
        assert_eq!(Value::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn obj_builder() {
        let v = obj([("x", Value::from(1usize)), ("y", Value::from("z"))]);
        assert_eq!(v.to_json(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn number_edge_cases() {
        // -0 parses, compares equal to 0, and survives a round-trip.
        let neg_zero = parse("-0").unwrap();
        assert_eq!(neg_zero, Value::Num(0.0));
        assert_eq!(parse(&neg_zero.to_json()).unwrap(), neg_zero);
        assert_eq!(parse("-0.0").unwrap().as_f64(), Some(0.0));

        // Exponent forms.
        assert_eq!(parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse("1E3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse("2.5e-2").unwrap(), Value::Num(0.025));
        assert_eq!(parse("-1.25E+2").unwrap(), Value::Num(-125.0));
        let big = parse("1e308").unwrap().as_f64().unwrap();
        assert!(big.is_finite());

        // Overflow to infinity is a parse error, not a silent inf.
        assert!(parse("1e999").is_err());
        assert!(parse("-1e999").is_err());
        assert!(parse("[1, 1e999]").is_err());

        // Malformed exponents rejected by f64::parse.
        assert!(parse("1e").is_err());
        assert!(parse("-").is_err());
    }

    #[test]
    fn non_finite_values_write_as_null() {
        assert_eq!(Value::Num(f64::INFINITY).to_json(), "null");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_json(), "null");
        assert_eq!(Value::Num(f64::NAN).to_json(), "null");
        // And the result is still valid JSON.
        assert!(parse(&Value::Arr(vec![Value::Num(f64::NAN)]).to_json()).is_ok());
    }

    #[test]
    fn escape_sequence_roundtrips() {
        // Every escape the writer can emit must parse back to itself.
        for s in [
            "plain",
            "quote\"inside",
            "back\\slash",
            "new\nline tab\t cr\r",
            "control\u{1}\u{1f}chars",
            "unicode héllo 世界 → ∞",
            "", // empty string
        ] {
            let v = Value::Str(s.to_string());
            let rt = parse(&v.to_json()).unwrap();
            assert_eq!(rt.as_str(), Some(s), "escaping broke {s:?}");
        }
        // \u escapes and solidus parse (writer never emits them for these).
        assert_eq!(parse(r#""A\/""#).unwrap().as_str(), Some("A/"));
    }

    #[test]
    fn codec_primitive_roundtrips() {
        fn rt<T: ToValue + FromValue + PartialEq + std::fmt::Debug>(x: T) {
            // Through the Value tree...
            assert_eq!(T::from_value(&x.to_value()).unwrap(), x);
            // ...and through the wire text.
            let text = x.to_value().to_json();
            assert_eq!(T::from_value(&parse(&text).unwrap()).unwrap(), x);
        }
        rt(true);
        rt(42.5f64);
        rt(0.55f32);
        rt(7usize);
        rt(7u64);
        rt("hello \"quoted\"".to_string());
        rt(vec![1.0f64, -2.5, 0.0]);
        rt(Some(3usize));
        rt(Option::<usize>::None);
        rt(vec![vec![1u64, 2], vec![]]);
    }

    #[test]
    fn codec_type_mismatches_error() {
        assert!(f64::from_value(&Value::Str("x".into())).is_err());
        assert!(bool::from_value(&Value::Num(1.0)).is_err());
        assert!(String::from_value(&Value::Null).is_err());
        assert!(usize::from_value(&Value::Num(-1.0)).is_err());
        assert!(usize::from_value(&Value::Num(1.5)).is_err());
        assert!(Vec::<f64>::from_value(&Value::Num(1.0)).is_err());
        // One bad element poisons the whole array decode.
        assert!(Vec::<f64>::from_value(&Value::Arr(vec![Value::Num(1.0), Value::Null])).is_err());
        let e = f64::from_value(&Value::Null).unwrap_err();
        assert!(format!("{e}").contains("number"));
    }

    #[test]
    fn property_roundtrip_random_values() {
        // Hand-rolled property test: random Value trees survive
        // write→parse round-trips. (No proptest in the vendor set.)
        use crate::util::Rng;
        fn gen(rng: &mut Rng, depth: usize) -> Value {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.below(2) == 0),
                2 => Value::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
                3 => {
                    let n = rng.below(8) as usize;
                    Value::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
                }
                4 => Value::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Value::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = Rng::new(2024);
        for _ in 0..500 {
            let v = gen(&mut rng, 3);
            let rt = parse(&v.to_json()).unwrap();
            assert_eq!(v, rt);
        }
    }
}
