//! Minimal JSON parser + writer.
//!
//! The image's vendored crate set has no `serde`/`serde_json`, so the
//! manifest loader ([`crate::config`]) and the serving wire protocol
//! ([`crate::server`]) use this self-contained implementation: a
//! recursive-descent parser into a [`Value`] tree plus an escaping
//! writer. Supports the full JSON grammar (RFC 8259) minus `\u` escapes
//! beyond the BMP surrogate-pair handling we don't need (artifact
//! manifests and wire messages are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ---- accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Value {
        const NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` chained for required fields with a readable error.
    pub fn req(&self, key: &str) -> Result<&Value, String> {
        match self {
            Value::Obj(o) => o.get(key).ok_or_else(|| format!("missing field {key:?}")),
            _ => Err(format!("expected object while reading {key:?}")),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    // ---- writer ----------------------------------------------------

    /// Serialize compactly (no whitespace).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                use std::fmt::Write;
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if (*n as f32) as f64 == *n {
                    // Exactly representable as f32 (the common case: our
                    // sensor data is f32 upcast for the wire). Rust's
                    // shortest-roundtrip float formatting then emits
                    // "0.55" instead of "0.550000011920929" — ~3× fewer
                    // bytes and ~2× faster serialization (§Perf).
                    let _ = write!(out, "{}", *n as f32);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- convenience constructors ---------------------------------------

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object value: `obj([("k", v.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Value)>>(fields: I) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---- parser ----------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at b.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("d"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nulll").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true}"#,
            r#"[1.5,-2,0]"#,
            r#""quote\" and backslash\\""#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let re = parse(&v.to_json()).unwrap();
            assert_eq!(v, re, "{c}");
        }
    }

    #[test]
    fn writer_escapes_control() {
        let v = Value::Str("a\u{1}b".into());
        assert_eq!(v.to_json(), "\"a\\u0001b\"");
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Value::Num(5.0).to_json(), "5");
        assert_eq!(Value::Num(5.5).to_json(), "5.5");
    }

    #[test]
    fn f32_values_write_short_and_roundtrip_at_f32() {
        // Contract: values exactly representable as f32 are emitted in
        // f32-shortest form. Round-tripping preserves the f32 value
        // (what the serving wire carries); f64s that are NOT f32-exact
        // keep full f64 round-tripping.
        let v = Value::Num(0.55f32 as f64);
        assert_eq!(v.to_json(), "0.55");
        let back = parse(&v.to_json()).unwrap().as_f64().unwrap();
        assert_eq!(back as f32, 0.55f32);

        let precise = Value::Num(0.1f64 + 0.2f64); // not f32-exact
        let back = parse(&precise.to_json()).unwrap();
        assert_eq!(back, precise);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "b": false, "a": []}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("n").as_f64(), Some(3.0));
        assert_eq!(v.get("b").as_bool(), Some(false));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 0);
        assert!(v.get("missing").is_null());
        assert!(v.req("missing").is_err());
        assert!(v.req("n").is_ok());
        assert_eq!(Value::Num(3.5).as_usize(), None);
        assert_eq!(Value::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn obj_builder() {
        let v = obj([("x", Value::from(1usize)), ("y", Value::from("z"))]);
        assert_eq!(v.to_json(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn property_roundtrip_random_values() {
        // Hand-rolled property test: random Value trees survive
        // write→parse round-trips. (No proptest in the vendor set.)
        use crate::util::Rng;
        fn gen(rng: &mut Rng, depth: usize) -> Value {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.below(2) == 0),
                2 => Value::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
                3 => {
                    let n = rng.below(8) as usize;
                    Value::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
                }
                4 => Value::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Value::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = Rng::new(2024);
        for _ in 0..500 {
            let v = gen(&mut rng, 3);
            let rt = parse(&v.to_json()).unwrap();
            assert_eq!(v, rt);
        }
    }
}
