//! Minimal dense f32 tensor.
//!
//! The native LSTM engine, the PJRT marshalling layer and the serving
//! protocol all move `[B, T, D]`-ish dense f32 data; this small row-major
//! container is all they need. It is deliberately not a general ndarray:
//! no broadcasting, no strides — shape + contiguous data + the couple of
//! ops the engine uses, each with debug-mode shape checks.

use std::fmt;

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Build from shape and data; panics if sizes disagree.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs {} elems", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape;
        self
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Slice `[i, :, :]` of a 3-D tensor.
    pub fn slab(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 3);
        let n = self.shape[1] * self.shape[2];
        &self.data[i * n..(i + 1) * n]
    }

    /// Index of the max element per row of a 2-D tensor (argmax, axis=1).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        (0..self.shape[0])
            .map(|i| {
                let row = self.row(i);
                let mut best = 0;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    /// Max |a - b| over all elements; shapes must match.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality within atol + rtol*|b| per element.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_size() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn new_rejects_mismatch() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn zeros_and_reshape() {
        let t = Tensor::zeros(vec![4, 2]).reshape(vec![2, 4]);
        assert_eq!(t.shape(), &[2, 4]);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    fn reshape_rejects_bad_count() {
        Tensor::zeros(vec![4]).reshape(vec![5]);
    }

    #[test]
    fn row_and_slab() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let t3 = Tensor::new(vec![2, 2, 2], (0..8).map(|v| v as f32).collect());
        assert_eq!(t3.slab(1), &[4., 5., 6., 7.]);
    }

    #[test]
    fn argmax_rows_ties_take_first() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 5.0, 5.0, 7.0, 1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0001, 3.0]);
        assert!(a.allclose(&b, 1e-3, 1e-3));
        assert!(!a.allclose(&b, 0.0, 1e-6));
        assert!((a.max_abs_diff(&b) - 1e-4).abs() < 1e-6);
    }
}
